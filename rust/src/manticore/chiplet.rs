//! One Manticore chiplet (paper Fig. 22): 128 clusters (1024 cores) in a
//! quadrant tree, one HBM2E controller with four 512-bit ports, L2
//! memory / PCIe / D2D modeled as an IO endpoint, and the two physically
//! separate networks (512-bit DMA tree, 64-bit core tree) built from the
//! §2 platform modules.
//!
//! The chiplet runs on the activity-tracked engine (`sim::engine`): every
//! cluster-internal module, endpoint, and tree-crosspoint *part* (each
//! per-port demux, mux, ID remapper, and input queue — see
//! `Crosspoint::into_parts`) registers individually in the engine arena,
//! so idle parts of the fabric are skipped entirely and a beat crossing a
//! node wakes only the ports on its path. External pokes keep working
//! through shared handles
//! (`ClusterHandle`): `Dma::submit` and `RwGen::set_cfg` wake their
//! engine components themselves. `ChipletCfg::full_scan` disables the
//! sleep/wake optimization for A/B measurements and determinism checks
//! (`benches/tab2_manticore.rs`, `rust/tests/engine_semantics.rs`).
//!
//! Scaling: the `fanout` vector controls the instance size. The paper
//! configuration is `[4, 4, 4, 2]` (128 clusters); tests use smaller
//! instances of the *same* code path (e.g. `[2, 2]` = 4 clusters).
//!
//! Parallel sharded mode (`ChipletCfg::threads >= 1`): every cluster
//! becomes its own `sim::shard` shard and the whole tree (plus the top
//! crosspoint, HBM, and IO) lives in shard 0; the four cluster uplink
//! bundles are cut with `protocol::exchange` relays and swapped at
//! epoch barriers. Because clusters only ever talk to the trees, the
//! shard structure is independent of the thread count, so results are
//! bit-identical for every `threads >= 1`
//! (`manticore::chiplet::determinism_fingerprint`,
//! `rust/tests/engine_semantics.rs`). `threads = 0` (the default) keeps
//! the single-arena engine with direct 1-cycle uplinks — a different,
//! slightly tighter timing model, so its results are compared against
//! its own full-scan oracle, not against sharded runs.

use std::cell::RefCell;
use std::rc::Rc;

use crate::collective::RankSchedule;
use crate::coordinator::report::Json;
use crate::manticore::cluster::{addr, core_net_cfg, dma_net_cfg, Cluster, ClusterHandle};
use crate::manticore::network::{build_tree, NodeIo, TreeCfg, UplinkTap};
use crate::noc::addr_decode::{AddrMap, AddrRule, DefaultPort};
use crate::noc::crosspoint::{Crosspoint, CrosspointCfg};
use crate::noc::dma::TransferReq;
use crate::noc::upsizer::Upsizer;
use crate::protocol::exchange::{cut_master_export, cut_slave_export};
use crate::protocol::{bundle, BundleCfg, MasterEnd};
use crate::sim::{shared, Arena, Component, Cycle, EngineOpts};
use crate::telemetry::{
    link_report_json, EnergyReport, LinkTap, TraceEvent, ON_DIE_PJ_PER_BYTE,
};
use crate::traffic::gen::RwGenCfg;
use crate::traffic::perfect_slave::PerfectSlave;

#[derive(Clone)]
pub struct ChipletCfg {
    /// Children per tree level, bottom-up. Paper: [4, 4, 4, 2].
    pub fanout: Vec<usize>,
    /// Core traffic generator template (per-cluster seed is derived; use
    /// `Cluster::cores.borrow_mut().set_cfg(..)` for per-cluster workloads).
    pub core_traffic: RwGenCfg,
    /// Concurrency budget: transactions per unique ID per network level.
    pub txns_per_id: u32,
    /// HBM access latency in cycles.
    pub hbm_latency: Cycle,
    /// Crosspoint input queue depth.
    pub input_queue: Option<usize>,
    /// Engine choice and mode (threads / exchange epoch / full-scan
    /// oracle), shared with every other stack via [`EngineOpts`]. All
    /// `threads >= 1` produce bit-identical results.
    pub engine: EngineOpts,
}

impl ChipletCfg {
    /// The paper's full configuration: 128 clusters / 1024 cores.
    pub fn full() -> Self {
        ChipletCfg {
            fanout: vec![4, 4, 4, 2],
            core_traffic: RwGenCfg { total: Some(0), ..Default::default() },
            txns_per_id: 8,
            hbm_latency: 50,
            input_queue: Some(4),
            engine: EngineOpts::default(),
        }
    }

    /// A small instance for CI: 4 clusters, same code path.
    pub fn small() -> Self {
        ChipletCfg { fanout: vec![2, 2], ..Self::full() }
    }

    pub fn n_clusters(&self) -> usize {
        self.fanout.iter().product()
    }
}

pub struct Chiplet {
    pub cfg: ChipletCfg,
    pub clusters: Vec<ClusterHandle>,
    arena: Arena,
    /// Per level (bottom-up), per node: DMA-tree uplink bandwidth taps.
    dma_taps: Vec<Vec<UplinkTap>>,
    core_taps: Vec<Vec<UplinkTap>>,
    /// Per-master-port bundle taps of every tree node and the top
    /// crosspoint (the telemetry link-utilization heatmap; empty when
    /// telemetry is off).
    link_taps: Vec<LinkTap>,
    pub hbm: Vec<Rc<RefCell<PerfectSlave>>>,
    pub io: Rc<RefCell<PerfectSlave>>,
    /// External master into the chiplet (PCIe/D2D side), for tests.
    pub io_in: MasterEnd,
    pub cycles: Cycle,
}

impl Chiplet {
    pub fn new(cfg: ChipletCfg) -> Self {
        let n = cfg.n_clusters();
        let dcfg = dma_net_cfg();
        let ccfg = core_net_cfg();
        let epoch = cfg.engine.epoch.max(1);

        // Shard 0 carries the trees and endpoints; cluster i lives in
        // shard i + 1. Clusters only talk to the trees, so the shard
        // structure (and therefore the result) is independent of how
        // many worker threads chunk the shards. `Arena::new` applies
        // threads/epoch/policy/full_scan itself; `epoch` stays local for
        // the cut-relay capacities below.
        let mut arena = Arena::new(&cfg.engine, n + 1);

        // --- Clusters + tree leaves ---
        // Registration order mirrors the old monolithic tick order:
        // cluster internals first, then tree nodes, then the top level.
        let mut clusters = Vec::with_capacity(n);
        let mut dma_leaves = Vec::with_capacity(n);
        let mut core_leaves = Vec::with_capacity(n);
        for i in 0..n {
            let mut tc = cfg.core_traffic.clone();
            tc.seed = 0x1000 + i as u64;
            let mut cl = Cluster::new(i, tc);
            let range = (addr::cluster_base(i), addr::cluster_base(i) + addr::CLUSTER_STRIDE);
            let dma_out = cl.dma_out.take().unwrap();
            let dma_in = cl.dma_l1_in.take().unwrap();
            let core_out = cl.core_out.take().unwrap();
            let core_in = cl.core_l1_in.take().unwrap();
            let (handle, comps) = cl.split();
            let (dma_io, core_io): (NodeIo, NodeIo) = match &mut arena {
                Arena::Single { engine, domain } => {
                    for c in comps {
                        engine.add_boxed(*domain, c);
                    }
                    (
                        NodeIo { up_out: dma_out, up_in: dma_in, range },
                        NodeIo { up_out: core_out, up_in: core_in, range },
                    )
                }
                Arena::Sharded { eng } => {
                    // Cut all four uplink bundles: the cluster-side relay
                    // halves join the cluster's shard, the tree-side halves
                    // join shard 0, and the fresh far ends become the tree
                    // leaves.
                    let (c_do, far_dma_out) =
                        cut_slave_export(&format!("cut.c{i}.dmaout"), dcfg, dma_out, epoch);
                    let (c_di, far_dma_in) =
                        cut_master_export(&format!("cut.c{i}.dmain"), dcfg, dma_in, epoch);
                    let (c_co, far_core_out) =
                        cut_slave_export(&format!("cut.c{i}.coreout"), ccfg, core_out, epoch);
                    let (c_ci, far_core_in) =
                        cut_master_export(&format!("cut.c{i}.corein"), ccfg, core_in, epoch);
                    // SAFETY: all four bundles leaving the cluster were
                    // cut just above, so everything registered in shard
                    // i+1 (cluster internals + near relay halves) shares
                    // `Rc` state only within that shard; the far halves
                    // join shard 0 and reach the cluster exclusively
                    // through the exchange queues. `register` also wires
                    // each queue's exchange wake to its relay, so the
                    // relays may sleep between exchanges. The
                    // `ClusterHandle` is only touched between runs.
                    unsafe {
                        let sh = eng.shard(i + 1);
                        for c in comps {
                            sh.add_boxed(c);
                        }
                        c_do.register(eng, i + 1, 0);
                        c_di.register(eng, 0, i + 1);
                        c_co.register(eng, i + 1, 0);
                        c_ci.register(eng, 0, i + 1);
                    }
                    (
                        NodeIo { up_out: far_dma_out, up_in: far_dma_in, range },
                        NodeIo { up_out: far_core_out, up_in: far_core_in, range },
                    )
                }
            };
            dma_leaves.push(dma_io);
            core_leaves.push(core_io);
            clusters.push(handle);
        }

        // --- The two trees ---
        // The last fanout level is realized by the top-level crosspoint
        // (the paper's L3 networks carry the HBM ports as feed-throughs,
        // Fig. 24b — attaching HBM above a single root uplink would funnel
        // the whole HBM bandwidth through one bundle).
        let tree_fanout: Vec<usize> = cfg.fanout[..cfg.fanout.len() - 1].to_vec();
        let mut dma_tree = build_tree(
            &TreeCfg {
                port_cfg: dcfg,
                fanout: tree_fanout.clone(),
                txns_per_id: cfg.txns_per_id,
                input_queue: cfg.input_queue,
                label: "dma".into(),
            },
            dma_leaves,
        );
        let mut core_tree = build_tree(
            &TreeCfg {
                port_cfg: ccfg,
                fanout: tree_fanout,
                txns_per_id: cfg.txns_per_id,
                input_queue: cfg.input_queue,
                label: "core".into(),
            },
            core_leaves,
        );
        let top_children = *cfg.fanout.last().unwrap();
        assert_eq!(dma_tree.roots.len(), top_children, "tree roots = last fanout level");
        let dma_roots: Vec<_> = dma_tree.roots.drain(..).collect();
        // The core tree still needs a single junction below the top: fold
        // its roots through one more crosspoint level if there are several.
        let core_root = if core_tree.roots.len() == 1 {
            core_tree.roots.pop().unwrap()
        } else {
            let roots: Vec<_> = core_tree.roots.drain(..).collect();
            let n_roots = roots.len();
            let mut t2 = build_tree(
                &TreeCfg {
                    port_cfg: ccfg,
                    fanout: vec![n_roots],
                    txns_per_id: cfg.txns_per_id,
                    input_queue: cfg.input_queue,
                    label: "coretop".into(),
                },
                roots,
            );
            core_tree.nodes.append(&mut t2.nodes);
            t2.roots.pop().unwrap()
        };
        let dma_taps = std::mem::take(&mut dma_tree.level_taps);
        let core_taps = std::mem::take(&mut core_tree.level_taps);
        // With telemetry on, keep each node's per-master-port bundle taps
        // for the link-utilization heatmap (passive counters; skipped
        // entirely when telemetry is off).
        let mut link_taps = Vec::new();
        // Finer wake granularity: each node's demux/mux/remapper/queue
        // registers individually, so a beat crossing a node wakes only the
        // ports on its path instead of the whole crosspoint. The parts are
        // added in the node's tick order, keeping results bit-identical to
        // monolithic registration.
        for mut node in dma_tree.nodes.drain(..) {
            if arena.telemetry_enabled() {
                link_taps.append(&mut node.take_link_taps());
            }
            for part in node.into_parts() {
                arena.add_infra(part);
            }
        }
        for mut node in core_tree.nodes.drain(..) {
            if arena.telemetry_enabled() {
                link_taps.append(&mut node.take_link_taps());
            }
            for part in node.into_parts() {
                arena.add_infra(part);
            }
        }

        // --- Top level ---
        let cluster_span = addr::cluster_base(n);
        let hbm_port_size = addr::HBM_SIZE / 4;
        let io_base = addr::HBM_BASE + addr::HBM_SIZE;

        // Core root 64b -> 512b upsizer (cores reach the wide HBM ports
        // through data width converters, Fig. 24b).
        let up_cfg = BundleCfg::new(512, ccfg.id_bits);
        let (coreup_m, coreup_s) = bundle("top.coreup", up_cfg);
        let core_upsizer = Upsizer::new("top.upsizer", core_root.up_out, coreup_m, 2);
        // No downward requests enter the core tree from the top.
        drop(core_root.up_in);

        // IO-in port (external masters: PCIe/D2D).
        let (io_in_m, io_in_s) = bundle("top.ioin", dcfg);

        assert_eq!(up_cfg.id_bits, dcfg.id_bits, "top ports must be isomorphous");
        let _ = cluster_span;
        let mut hbm_masters = Vec::new();
        let mut hbm = Vec::new();
        let mut io_components: Vec<Box<dyn Component>> = Vec::new();
        for p in 0..4 {
            let (m, s) = bundle(&format!("top.hbm{p}"), dcfg);
            hbm_masters.push(m);
            let (ps, adapter) = shared(PerfectSlave::new(format!("hbm{p}"), s, cfg.hbm_latency));
            io_components.push(Box::new(adapter));
            hbm.push(ps);
        }
        let (io_out_m, io_out_s) = bundle("top.io", dcfg);
        let (io, io_adapter) = shared(PerfectSlave::new("io", io_out_s, 20));
        io_components.push(Box::new(io_adapter));

        // Top crosspoint: slave ports = the DMA subtree uplinks + the
        // upsized core network + IO-in; master ports = downlinks into each
        // subtree + the four HBM ports + IO-out.
        let mut slaves = Vec::new();
        let mut masters = Vec::new();
        let mut rules = Vec::new();
        for (i, root) in dma_roots.into_iter().enumerate() {
            rules.push(AddrRule::new(root.range.0, root.range.1, i));
            slaves.push(root.up_out);
            masters.push(root.up_in);
        }
        let nd = rules.len();
        for p in 0..4u64 {
            rules.push(AddrRule::new(
                addr::HBM_BASE + p * hbm_port_size,
                addr::HBM_BASE + (p + 1) * hbm_port_size,
                nd + p as usize,
            ));
        }
        rules.push(AddrRule::new(io_base, io_base + (1 << 30), nd + 4));
        let map = AddrMap::new(rules, DefaultPort::Error);
        slaves.push(coreup_s);
        slaves.push(io_in_s);
        masters.extend(hbm_masters);
        masters.push(io_out_m);
        let n_s = slaves.len();
        let n_m = masters.len();
        let mut top = Crosspoint::new(
            "top",
            slaves,
            masters,
            CrosspointCfg {
                port_cfg: dcfg,
                maps: vec![map; n_s],
                connectivity: vec![vec![true; n_m]; n_s],
                txns_per_id: cfg.txns_per_id,
                input_queue: cfg.input_queue,
                max_txns_per_id: cfg.txns_per_id,
            },
        );
        if arena.telemetry_enabled() {
            link_taps.append(&mut top.take_link_taps());
        }
        arena.add_infra(Box::new(core_upsizer));
        for part in top.into_parts() {
            arena.add_infra(part);
        }
        for c in io_components {
            arena.add_infra(c);
        }

        // With telemetry on, hand each cluster's DMA engines and
        // collective unit a tracer onto their own shard's ring (shard
        // i + 1 in sharded mode; the single arena ignores the index).
        if arena.telemetry_enabled() {
            for (i, c) in clusters.iter().enumerate() {
                if let Some(tr) = arena.tracer(i + 1) {
                    for dma in &c.dma {
                        dma.borrow_mut().set_tracer(tr.clone());
                    }
                    c.coll.borrow_mut().set_tracer(tr);
                }
            }
        }

        Chiplet {
            cfg,
            clusters,
            arena,
            dma_taps,
            core_taps,
            link_taps,
            hbm,
            io,
            io_in: io_in_m,
            cycles: 0,
        }
    }

    /// Submit a DMA transfer on a cluster engine (wakes it if asleep).
    pub fn submit_dma(&self, cluster: usize, engine: usize, req: TransferReq) -> u64 {
        self.clusters[cluster].dma[engine].borrow_mut().submit(req)
    }

    /// Submit a chained DMA descriptor list on a cluster engine.
    pub fn submit_dma_chain(
        &self,
        cluster: usize,
        engine: usize,
        reqs: impl IntoIterator<Item = TransferReq>,
    ) -> u64 {
        self.clusters[cluster].dma[engine].borrow_mut().submit_chain(reqs)
    }

    pub fn dma_done(&self, cluster: usize, engine: usize, handle: u64) -> bool {
        self.clusters[cluster].dma[engine].borrow().completions.contains(&handle)
    }

    /// Load a collective rank program onto a cluster's orchestrator
    /// (wakes it if asleep). Call between runs only.
    pub fn submit_collective(&self, cluster: usize, sched: RankSchedule) {
        self.clusters[cluster].coll.borrow_mut().submit(sched);
    }

    /// Whether a cluster's collective program has fully completed.
    pub fn collective_done(&self, cluster: usize) -> bool {
        self.clusters[cluster].coll.borrow().done()
    }

    /// Whether every cluster's collective program has completed.
    pub fn all_collectives_done(&self) -> bool {
        self.clusters.iter().all(|c| c.coll.borrow().done())
    }

    /// Aggregate data bytes moved at all cluster DMA ports.
    pub fn total_dma_bytes(&self) -> u64 {
        self.clusters.iter().map(|c| c.dma_bytes()).sum()
    }

    /// Data bytes that crossed each DMA-tree level's uplinks (bottom-up:
    /// L1-quadrant uplinks first). Both directions, W + R channels.
    pub fn dma_level_bytes(&self) -> Vec<u64> {
        let bb = dma_net_cfg().beat_bytes() as u64;
        self.dma_taps
            .iter()
            .map(|taps| taps.iter().map(|t| t.data_beats()).sum::<u64>() * bb)
            .collect()
    }

    /// Same for the core network (64-bit beats).
    pub fn core_level_bytes(&self) -> Vec<u64> {
        let bb = core_net_cfg().beat_bytes() as u64;
        self.core_taps
            .iter()
            .map(|taps| taps.iter().map(|t| t.data_beats()).sum::<u64>() * bb)
            .collect()
    }

    /// Total bytes served by the HBM ports (read + write).
    pub fn hbm_bytes(&self) -> u64 {
        self.hbm
            .iter()
            .map(|h| {
                let h = h.borrow();
                h.bytes_read + h.bytes_written
            })
            .sum()
    }

    /// Components currently awake in the engine (observability/benches).
    /// Cut relays sleep between exchanges like everything else (the
    /// epoch exchange wakes exactly the relays that gained beats or
    /// credits), so an idle sharded fabric reaches zero awake
    /// components (`idle_sharded_chiplet_sleeps_everything`).
    pub fn awake_components(&self) -> usize {
        self.arena.awake_components()
    }

    /// Total registered components.
    pub fn component_count(&self) -> usize {
        self.arena.component_count()
    }

    /// The sharded engine's accumulated cycle profile — per-shard run
    /// time and awake-integral, per-worker stall/exchange split, and the
    /// run/sprint/exchange counters (`None` in single-arena mode).
    pub fn shard_profile(&self) -> Option<crate::sim::ShardProfileReport> {
        self.arena.shard_profile()
    }

    /// Worker threads driving the simulation (0 = single-arena engine).
    pub fn threads(&self) -> usize {
        self.cfg.engine.worker_threads()
    }

    /// Whether the telemetry layer (meter + tracers + link taps) is on.
    pub fn telemetry_enabled(&self) -> bool {
        self.arena.telemetry_enabled()
    }

    /// Drain the trace rings into one canonically sorted event stream
    /// plus the total drop count (empty when telemetry is off). Call
    /// between runs.
    pub fn take_trace_events(&mut self) -> (Vec<TraceEvent>, u64) {
        self.arena.take_trace_events()
    }

    /// Energy spent so far: every component's metered active-cycle count
    /// through the §3 area model, plus per-byte wire energy on every
    /// tapped network bundle. Empty (zero totals) when telemetry is off.
    pub fn energy_report(&self) -> EnergyReport {
        let mut r = EnergyReport::new(self.cycles);
        for (name, active) in self.arena.meter_rows() {
            r.add_component(&name, active);
        }
        for t in &self.link_taps {
            r.add_link(t.label(), t.bytes(), ON_DIE_PJ_PER_BYTE);
        }
        r
    }

    /// Link-utilization heatmap over all tapped network bundles (tree
    /// node ports + top crosspoint ports). Empty when telemetry is off.
    pub fn link_report(&self) -> Json {
        let usages: Vec<_> = self.link_taps.iter().map(|t| t.usage(self.cycles)).collect();
        link_report_json(&usages, self.cycles)
    }

    /// Advance one cycle. Per-cycle stepping is always serial, even in
    /// sharded mode (callers like `run_scripts` poke cluster handles
    /// between steps, which requires quiescent shards); parallelism
    /// comes from batched `run`/`run_until` windows.
    pub fn step(&mut self) {
        self.run(1);
    }

    pub fn run(&mut self, cycles: Cycle) {
        // In sharded mode this is one parallel batch: the worker threads
        // only join at epoch barriers instead of every cycle.
        self.arena.advance(cycles);
        self.cycles += cycles;
        debug_assert_eq!(self.arena.cycles(), self.cycles);
        // Keep the external IO bundle's clock fresh so out-of-engine
        // masters can push commands with current timestamps.
        self.io_in.set_now(self.cycles);
    }

    /// Run until `pred` holds or the budget expires. In sharded mode the
    /// predicate (which reads cluster handles owned by worker threads
    /// mid-run) is evaluated only at epoch boundaries, so the stopping
    /// cycle — and everything downstream of it — is identical for every
    /// thread count (in single-arena mode it degrades to per-cycle
    /// checks).
    pub fn run_until(&mut self, budget: Cycle, mut pred: impl FnMut(&Chiplet) -> bool) -> bool {
        let mut left = budget;
        while left > 0 {
            let step = self.arena.to_next_exchange().min(left);
            self.run(step);
            left -= step;
            if pred(self) {
                return true;
            }
        }
        false
    }
}

/// Canonical rendering of everything the engine choice (single-arena vs
/// sharded, event vs full-scan, any worker-thread count) must leave
/// unchanged: per-cluster DMA and core-generator results, per-level tree
/// traffic, and endpoint byte counters. Two sharded runs of the same
/// workload must produce byte-identical fingerprints for every
/// `threads >= 1` (`rust/tests/engine_semantics.rs`).
pub fn determinism_fingerprint(ch: &Chiplet) -> String {
    let clusters: Vec<Json> = ch
        .clusters
        .iter()
        .map(|c| {
            let cores = c.cores.borrow();
            let s = &cores.stats;
            let coll = c.coll.borrow();
            Json::Obj(vec![
                ("dma_bytes".into(), Json::Num(c.dma_bytes() as f64)),
                ("core_issued".into(), Json::Num(s.issued as f64)),
                ("core_completed".into(), Json::Num(s.completed as f64)),
                ("core_bytes".into(), Json::Num(s.bytes as f64)),
                ("core_read_lat_mean".into(), Json::Num(s.read_latency.mean())),
                ("core_data_errors".into(), Json::Num(s.data_errors as f64)),
                ("coll_ops".into(), Json::Num(coll.stats.ops_completed as f64)),
                ("coll_reduced".into(), Json::Num(coll.stats.reduced_bytes as f64)),
                ("coll_chains".into(), Json::Num(coll.stats.chains_submitted as f64)),
            ])
        })
        .collect();
    let hbm: Vec<Json> = ch
        .hbm
        .iter()
        .map(|h| {
            let h = h.borrow();
            Json::Arr(vec![Json::Num(h.bytes_read as f64), Json::Num(h.bytes_written as f64)])
        })
        .collect();
    let level = |bytes: Vec<u64>| Json::Arr(bytes.iter().map(|&b| Json::Num(b as f64)).collect());
    let io = ch.io.borrow();
    Json::Obj(vec![
        ("cycles".into(), Json::Num(ch.cycles as f64)),
        ("clusters".into(), Json::Arr(clusters)),
        ("dma_level_bytes".into(), level(ch.dma_level_bytes())),
        ("core_level_bytes".into(), level(ch.core_level_bytes())),
        ("hbm".into(), Json::Arr(hbm)),
        (
            "io".into(),
            Json::Arr(vec![Json::Num(io.bytes_read as f64), Json::Num(io.bytes_written as f64)]),
        ),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::gen::AddrPattern;

    #[test]
    fn small_chiplet_cross_cluster_dma() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        // Cluster 0 copies 1 KiB from cluster 3's L1 into its own L1.
        let src_base = addr::cluster_base(3) + 0x2000;
        let dst_base = addr::cluster_base(0) + 0x4000;
        let data: Vec<u8> = (0..1024).map(|i| (i % 241) as u8).collect();
        ch.clusters[3].l1.borrow().banks.borrow_mut().poke(src_base, &data);
        let h = ch.submit_dma(0, 0, TransferReq::OneD { src: src_base, dst: dst_base, len: 1024 });
        let ok = ch.run_until(20_000, |c| c.dma_done(0, 0, h));
        assert!(ok, "cross-cluster DMA must complete");
        assert_eq!(ch.clusters[0].l1.borrow().banks.borrow().peek_vec(dst_base, 1024), data);
    }

    #[test]
    fn small_chiplet_hbm_read() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        // Cluster 1 streams 4 KiB from HBM into its L1.
        let dst = addr::cluster_base(1) + 0x1000;
        let h = ch.submit_dma(
            1,
            0,
            TransferReq::OneD { src: addr::HBM_BASE + 0x10000, dst, len: 4096 },
        );
        let ok = ch.run_until(40_000, |c| c.dma_done(1, 0, h));
        assert!(ok, "HBM read must complete");
        // Data matches the HBM pattern.
        let got = ch.clusters[1].l1.borrow().banks.borrow().peek_vec(dst, 64);
        let expect: Vec<u8> = (0..64)
            .map(|j| crate::traffic::perfect_slave::pattern_byte(addr::HBM_BASE + 0x10000 + j))
            .collect();
        assert_eq!(got, expect);
        assert!(ch.hbm_bytes() >= 4096);
    }

    #[test]
    fn small_chiplet_hbm_write() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        let src = addr::cluster_base(2) + 0x800;
        ch.clusters[2].l1.borrow().banks.borrow_mut().poke(src, &[0x77; 256]);
        let h = ch.submit_dma(
            2,
            1,
            TransferReq::OneD { src, dst: addr::HBM_BASE + 0x1000, len: 256 },
        );
        let ok = ch.run_until(40_000, |c| c.dma_done(2, 1, h));
        assert!(ok, "HBM write must complete");
        assert!(ch.hbm[0].borrow().bytes_written >= 256);
    }

    #[test]
    fn core_reads_remote_cluster_over_core_net() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        // Enable cluster 0's cores: read from cluster 2's L1.
        ch.clusters[0].cores.borrow_mut().set_cfg(RwGenCfg {
            pattern: AddrPattern::Uniform { base: addr::cluster_base(2), span: 0x4000 },
            p_read: 1.0,
            total: Some(20),
            max_outstanding: 4,
            verify: false, // L1 starts zeroed; pattern does not apply
            seed: 7,
            ..Default::default()
        });
        let ok = ch.run_until(50_000, |c| c.clusters[0].cores.borrow().done());
        assert!(ok, "remote core reads must complete");
        let stats = ch.clusters[0].cores.borrow().stats.clone();
        assert_eq!(stats.completed, 20);
        assert!(stats.read_latency.mean() > 5.0, "crossing the tree takes cycles");
    }

    #[test]
    fn core_reads_hbm_through_dwc() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        ch.clusters[1].cores.borrow_mut().set_cfg(RwGenCfg {
            pattern: AddrPattern::Uniform { base: addr::HBM_BASE, span: 0x10000 },
            p_read: 1.0,
            total: Some(10),
            max_outstanding: 2,
            verify: true, // HBM returns the perfect pattern
            seed: 9,
            ..Default::default()
        });
        let ok = ch.run_until(100_000, |c| c.clusters[1].cores.borrow().done());
        assert!(ok, "core HBM reads must complete");
        let stats = ch.clusters[1].cores.borrow().stats.clone();
        assert_eq!(stats.completed, 10);
        assert_eq!(stats.data_errors, 0, "data intact through upsizer + top + HBM");
    }

    #[test]
    fn io_master_reaches_cluster_l1() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        ch.clusters[0].l1.borrow().banks.borrow_mut().poke(addr::cluster_base(0), &[0x42; 64]);
        // External master (PCIe model) reads cluster 0's L1.
        ch.io_in.set_now(0);
        let mut c = crate::protocol::Cmd::new(1, addr::cluster_base(0), 0, 6);
        c.tag = 5;
        ch.io_in.ar.push(c);
        let mut got = None;
        for _ in 0..20_000 {
            ch.step();
            ch.io_in.set_now(ch.cycles);
            if ch.io_in.r.can_pop() {
                got = Some(ch.io_in.r.pop());
                break;
            }
        }
        let r = got.expect("IO read must complete");
        assert_eq!(&r.data.as_slice()[..8], &[0x42; 8]);
    }

    #[test]
    fn idle_chiplet_sleeps_almost_everything() {
        // With no traffic, nearly the whole fabric must go to sleep.
        let mut ch = Chiplet::new(ChipletCfg::small());
        ch.run(100);
        let awake = ch.awake_components();
        let total = ch.component_count();
        assert!(
            awake * 10 <= total,
            "idle fabric should sleep: {awake}/{total} components awake"
        );
    }

    #[test]
    fn idle_sharded_chiplet_sleeps_everything() {
        // Cut relays are woken by the epoch exchange only when it moves
        // beats or credits toward them, so a truly idle sharded fabric
        // must reach zero awake components — the relays were the last
        // permanently-awake holdouts.
        let mut cfg = ChipletCfg::small();
        cfg.engine = EngineOpts::sharded(2, 4);
        let mut ch = Chiplet::new(cfg);
        ch.run(200);
        assert_eq!(
            ch.awake_components(),
            0,
            "idle sharded chiplet must be fully asleep ({} components registered)",
            ch.component_count()
        );
        // ...and further idle epochs keep it asleep.
        ch.run(100);
        assert_eq!(ch.awake_components(), 0);
        // The fabric must still wake up for real traffic afterwards.
        let src = addr::cluster_base(1) + 0x2000;
        let dst = addr::cluster_base(0) + 0x2000;
        ch.clusters[1].l1.borrow().banks.borrow_mut().poke(src, &[0x3C; 256]);
        let h = ch.submit_dma(0, 0, TransferReq::OneD { src, dst, len: 256 });
        let ok = ch.run_until(40_000, |c| c.dma_done(0, 0, h));
        assert!(ok, "DMA after the idle period must complete through sleeping cuts");
        assert_eq!(ch.clusters[0].l1.borrow().banks.borrow().peek_vec(dst, 256), vec![0x3C; 256]);
    }

    #[test]
    fn sharded_chiplet_cross_cluster_dma() {
        // The same copy as `small_chiplet_cross_cluster_dma`, but with
        // every cluster in its own shard and two worker threads: data
        // must arrive intact through the epoch-exchange cuts.
        let mut cfg = ChipletCfg::small();
        cfg.engine = EngineOpts::sharded(2, 4);
        let mut ch = Chiplet::new(cfg);
        let src_base = addr::cluster_base(3) + 0x2000;
        let dst_base = addr::cluster_base(0) + 0x4000;
        let data: Vec<u8> = (0..1024).map(|i| (i % 241) as u8).collect();
        ch.clusters[3].l1.borrow().banks.borrow_mut().poke(src_base, &data);
        let h = ch.submit_dma(0, 0, TransferReq::OneD { src: src_base, dst: dst_base, len: 1024 });
        let ok = ch.run_until(40_000, |c| c.dma_done(0, 0, h));
        assert!(ok, "cross-cluster DMA must complete through the cuts");
        assert_eq!(ch.clusters[0].l1.borrow().banks.borrow().peek_vec(dst_base, 1024), data);
    }

    #[test]
    fn sharded_chiplet_hbm_read_verifies_pattern() {
        let mut cfg = ChipletCfg::small();
        cfg.engine = EngineOpts::sharded(3, 8);
        let mut ch = Chiplet::new(cfg);
        let dst = addr::cluster_base(1) + 0x1000;
        let h = ch.submit_dma(
            1,
            0,
            TransferReq::OneD { src: addr::HBM_BASE + 0x10000, dst, len: 4096 },
        );
        let ok = ch.run_until(80_000, |c| c.dma_done(1, 0, h));
        assert!(ok, "HBM read must complete through the cuts");
        let got = ch.clusters[1].l1.borrow().banks.borrow().peek_vec(dst, 64);
        let expect: Vec<u8> = (0..64)
            .map(|j| crate::traffic::perfect_slave::pattern_byte(addr::HBM_BASE + 0x10000 + j))
            .collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn telemetry_reports_energy_trace_and_links() {
        let mut cfg = ChipletCfg::small();
        cfg.engine.telemetry = true;
        let mut ch = Chiplet::new(cfg);
        assert!(ch.telemetry_enabled());
        let src = addr::cluster_base(3) + 0x2000;
        let dst = addr::cluster_base(0) + 0x4000;
        ch.clusters[3].l1.borrow().banks.borrow_mut().poke(src, &[0xA5; 512]);
        let h = ch.submit_dma(0, 0, TransferReq::OneD { src, dst, len: 512 });
        assert!(ch.run_until(20_000, |c| c.dma_done(0, 0, h)));
        let e = ch.energy_report();
        assert!(e.total_fj() > 0, "a DMA burns energy");
        // Exact conservation: line items sum to the total.
        let line_sum: u64 = e.comps.iter().map(|c| c.dyn_fj + c.static_fj).sum::<u64>()
            + e.links.iter().map(|l| l.fj).sum::<u64>();
        assert_eq!(line_sum, e.total_fj());
        assert!(e.links.iter().any(|l| l.bytes > 0), "the copy crossed tapped bundles");
        let (evs, dropped) = ch.take_trace_events();
        assert_eq!(dropped, 0);
        assert!(evs.iter().any(|ev| ev.name.ends_with(".leg")), "DMA leg spans traced");
        assert!(evs.iter().any(|ev| ev.dur > 0), "busy spans traced");
        let j = ch.link_report().render();
        assert!(j.contains("\"links\":["), "{j}");

        // Telemetry off (the default): all reports are empty.
        let mut off = Chiplet::new(ChipletCfg::small());
        off.run(10);
        assert!(!off.telemetry_enabled());
        assert_eq!(off.energy_report().total_fj(), 0);
        assert_eq!(off.take_trace_events(), (Vec::new(), 0));
    }

    #[test]
    fn full_scan_mode_matches_sleep_mode() {
        // The determinism oracle at unit scale: the same DMA produces the
        // same completion cycle and byte counters in both engine modes.
        let run = |full_scan: bool| {
            let mut cfg = ChipletCfg::small();
            cfg.engine.full_scan = full_scan;
            let mut ch = Chiplet::new(cfg);
            let src = addr::cluster_base(3) + 0x2000;
            let dst = addr::cluster_base(0) + 0x4000;
            ch.clusters[3].l1.borrow().banks.borrow_mut().poke(src, &[0xA5; 512]);
            let h = ch.submit_dma(0, 0, TransferReq::OneD { src, dst, len: 512 });
            let ok = ch.run_until(20_000, |c| c.dma_done(0, 0, h));
            assert!(ok);
            (ch.cycles, ch.total_dma_bytes(), ch.dma_level_bytes())
        };
        assert_eq!(run(false), run(true), "sleep/wake must not change simulated behaviour");
    }
}
