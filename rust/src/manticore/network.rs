//! Hierarchical tree network builder (paper §4.1/4.2, Figs 23/24).
//!
//! Manticore's on-chip network is a tree of fully-connected crosspoints:
//! four clusters form an L1 quadrant, four L1 quadrants an L2 quadrant,
//! four L2 quadrants an L3 quadrant, two L3 quadrants a chiplet. Each node
//! is one of our crosspoints (§2.2.2) with four downlinks and one uplink
//! per side; ID remappers inside the crosspoints keep all ports
//! isomorphous and enforce the per-level concurrency budgets (annotations
//! ①–⑩ in Fig. 23). Register stages cut all paths at the uplink ports
//! (challenge ⑥ in Fig. 24), which the model reflects as one cycle per
//! channel per hop.
//!
//! The same builder constructs both physically-separate networks: the
//! 512-bit DMA network and the 64-bit core network (design goal D4).
//!
//! Engine integration: the chiplet drains `Tree::nodes` after
//! construction and registers each node's per-port parts individually
//! (`Crosspoint::into_parts`), so an idle subtree sleeps port-by-port and
//! a beat arriving anywhere wakes only the demux/mux/remapper stages on
//! its path — not whole crosspoints. `Tree::level_taps` stays behind for
//! bandwidth accounting. A node can still register monolithically via its
//! `Component` impl (`Crosspoint::bind` forwards one `ComponentId` to all
//! internal channels), which standalone tests and benches use.

use crate::noc::addr_decode::{AddrMap, AddrRule, DefaultPort};
use crate::noc::crosspoint::{Crosspoint, CrosspointCfg};
use crate::protocol::{bundle, BundleCfg, MasterEnd, SlaveEnd};

/// What a tree node (or leaf) exposes to its parent.
pub struct NodeIo {
    /// Traffic flowing *out* of the subtree (parent consumes this end).
    pub up_out: SlaveEnd,
    /// Parent drives traffic *into* the subtree here.
    pub up_in: MasterEnd,
    /// Contiguous address range the subtree owns.
    pub range: (u64, u64),
}

/// Tree construction parameters.
pub struct TreeCfg {
    pub port_cfg: BundleCfg,
    /// Children per node, bottom level first (e.g. [4, 4, 4, 2]).
    pub fanout: Vec<usize>,
    /// Transactions per unique ID in the crosspoint remappers (per-level
    /// concurrency budget; Fig. 23 annotations).
    pub txns_per_id: u32,
    /// Input queue depth at crosspoint slave ports.
    pub input_queue: Option<usize>,
    /// Label prefix ("dma" / "core").
    pub label: String,
}

/// Bandwidth taps on one node's uplink: data channels in both directions.
pub struct UplinkTap {
    /// W data flowing up and into the node from above.
    pub w_up: crate::protocol::channel::Tap<crate::protocol::WBeat>,
    pub r_up: crate::protocol::channel::Tap<crate::protocol::RBeat>,
    pub w_down: crate::protocol::channel::Tap<crate::protocol::WBeat>,
    pub r_down: crate::protocol::channel::Tap<crate::protocol::RBeat>,
}

impl UplinkTap {
    /// Total data beats observed on this uplink (both directions).
    pub fn data_beats(&self) -> u64 {
        self.w_up.stats().handshakes
            + self.r_up.stats().handshakes
            + self.w_down.stats().handshakes
            + self.r_down.stats().handshakes
    }
}

/// One constructed network level.
pub struct Tree {
    pub nodes: Vec<Crosspoint>,
    /// Roots after the last level (≥1; the chiplet top ties them together).
    pub roots: Vec<NodeIo>,
    /// Per level (bottom-up), per node: uplink bandwidth taps.
    pub level_taps: Vec<Vec<UplinkTap>>,
}

/// Build the tree bottom-up from leaf NodeIos (cluster ports).
pub fn build_tree(cfg: &TreeCfg, leaves: Vec<NodeIo>) -> Tree {
    let mut nodes = Vec::new();
    let mut level_taps = Vec::new();
    let mut level_ios = leaves;
    for (lvl, &fanout) in cfg.fanout.iter().enumerate() {
        assert!(fanout >= 1);
        assert_eq!(
            level_ios.len() % fanout,
            0,
            "level {lvl}: {} children do not divide by fanout {fanout}",
            level_ios.len()
        );
        // Split the level into owned groups of `fanout` children.
        let mut groups: Vec<Vec<NodeIo>> = Vec::new();
        {
            let mut it = level_ios.into_iter();
            loop {
                let g: Vec<NodeIo> = it.by_ref().take(fanout).collect();
                if g.is_empty() {
                    break;
                }
                groups.push(g);
            }
        }
        let mut new_ios = Vec::new();
        let mut taps = Vec::new();
        for (gi, group) in groups.into_iter().enumerate() {
            let name = format!("{}.l{}n{}", cfg.label, lvl + 1, gi);
            // Node slave ports: children up_out + our uplink-in.
            // Node master ports: children up_in + our uplink-out.
            let (upl_in_m, upl_in_s) = bundle(&format!("{name}.upin"), cfg.port_cfg);
            let (upl_out_m, upl_out_s) = bundle(&format!("{name}.upout"), cfg.port_cfg);
            taps.push(UplinkTap {
                w_up: upl_out_m.w.tap(),
                r_up: upl_out_m.r.tap(),
                w_down: upl_in_m.w.tap(),
                r_down: upl_in_m.r.tap(),
            });
            let range = (group[0].range.0, group[fanout - 1].range.1);
            // Address rules: child i's range -> master port i.
            let rules: Vec<AddrRule> = group
                .iter()
                .enumerate()
                .map(|(i, io)| AddrRule::new(io.range.0, io.range.1, i))
                .collect();
            let child_map = AddrMap::new(rules.clone(), DefaultPort::Port(fanout));
            // Traffic arriving on the uplink must never route back up.
            let uplink_map = AddrMap::new(rules, DefaultPort::Error);
            let mut maps = vec![child_map; fanout];
            maps.push(uplink_map);
            // Connectivity: full except uplink-slave -> uplink-master.
            let mut connectivity = vec![vec![true; fanout + 1]; fanout + 1];
            connectivity[fanout][fanout] = false;
            let xp_cfg = CrosspointCfg {
                port_cfg: cfg.port_cfg,
                maps,
                connectivity,
                txns_per_id: cfg.txns_per_id,
                input_queue: cfg.input_queue,
                max_txns_per_id: cfg.txns_per_id,
            };
            let mut slaves = Vec::new();
            let mut masters = Vec::new();
            for io in group {
                slaves.push(io.up_out);
                masters.push(io.up_in);
            }
            slaves.push(upl_in_s);
            masters.push(upl_out_m);
            nodes.push(Crosspoint::new(name, slaves, masters, xp_cfg));
            new_ios.push(NodeIo { up_out: upl_out_s, up_in: upl_in_m, range });
        }
        level_ios = new_ios;
        level_taps.push(taps);
    }
    Tree { nodes, roots: level_ios, level_taps }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::payload::{Bytes, Cmd, RBeat, Resp};
    use crate::sim::{Component, Cycle};

    /// Build a 2-level tree over 4 synthetic leaves and check cross-subtree
    /// routing end-to-end.
    fn mk_leaves(n: usize, cfg: BundleCfg) -> (Vec<MasterEnd>, Vec<NodeIo>, Vec<SlaveEnd>) {
        let mut drive = Vec::new();
        let mut ios = Vec::new();
        let mut recv = Vec::new();
        for i in 0..n {
            let (out_m, out_s) = bundle(&format!("leaf{i}.out"), cfg);
            let (in_m, in_s) = bundle(&format!("leaf{i}.in"), cfg);
            drive.push(out_m);
            recv.push(in_s);
            ios.push(NodeIo {
                up_out: out_s,
                up_in: in_m,
                range: (i as u64 * 0x1000, (i as u64 + 1) * 0x1000),
            });
        }
        (drive, ios, recv)
    }

    #[test]
    fn cross_subtree_read_roundtrip() {
        let cfg = BundleCfg::new(64, 4);
        let (drive, leaves, recv) = mk_leaves(4, cfg);
        let mut tree = build_tree(
            &TreeCfg {
                port_cfg: cfg,
                fanout: vec![2, 2],
                txns_per_id: 8,
                input_queue: None,
                label: "t".into(),
            },
            leaves,
        );
        assert_eq!(tree.nodes.len(), 3, "2 L1 nodes + 1 root");
        assert_eq!(tree.roots.len(), 1);
        // Leaf 0 reads from leaf 3 (other subtree).
        let mut cy: Cycle = 0;
        drive[0].set_now(cy);
        let mut c = Cmd::new(1, 3 * 0x1000 + 0x40, 0, 3);
        c.tag = 42;
        drive[0].ar.push(c);
        let mut done = false;
        for _ in 0..100 {
            cy += 1;
            for d in &drive {
                d.set_now(cy);
            }
            for r in &recv {
                r.set_now(cy);
            }
            for n in &mut tree.nodes {
                n.tick(cy);
            }
            if recv[3].ar.can_pop() {
                let c = recv[3].ar.pop();
                recv[3].r.push(RBeat {
                    id: c.id,
                    data: Bytes::zeroed(8),
                    resp: Resp::Okay,
                    last: true,
                    tag: c.tag,
                });
            }
            if drive[0].r.can_pop() {
                let r = drive[0].r.pop();
                assert_eq!(r.tag, 42);
                done = true;
                break;
            }
        }
        assert!(done, "cross-subtree read must complete");
    }

    #[test]
    fn out_of_range_addr_gets_decerr_at_root() {
        let cfg = BundleCfg::new(64, 4);
        let (drive, leaves, recv) = mk_leaves(4, cfg);
        let mut tree = build_tree(
            &TreeCfg {
                port_cfg: cfg,
                fanout: vec![2, 2],
                txns_per_id: 8,
                input_queue: None,
                label: "t".into(),
            },
            leaves,
        );
        // Root uplink unconnected: address beyond all leaves exits at the
        // root's uplink; nothing answers, so instead target an address
        // that maps to no child from the *uplink side*: push into the root
        // from above.
        let root = &tree.roots[0];
        let mut cy = 0;
        root.up_in.set_now(cy);
        let mut c = Cmd::new(0, 0xFFFF_0000, 0, 3);
        c.tag = 7;
        root.up_in.ar.push(c);
        let mut got = None;
        for _ in 0..60 {
            cy += 1;
            root.up_in.set_now(cy);
            for d in &drive {
                d.set_now(cy);
            }
            for r in &recv {
                r.set_now(cy);
            }
            for n in &mut tree.nodes {
                n.tick(cy);
            }
            if root.up_in.r.can_pop() {
                got = Some(root.up_in.r.pop());
            }
        }
        assert_eq!(got.expect("DECERR from uplink map").resp, Resp::DecErr);
    }

    #[test]
    fn sibling_traffic_stays_local() {
        // Leaf 0 -> leaf 1 traffic must not appear at the root uplink.
        let cfg = BundleCfg::new(64, 4);
        let (drive, leaves, recv) = mk_leaves(4, cfg);
        let mut tree = build_tree(
            &TreeCfg {
                port_cfg: cfg,
                fanout: vec![2, 2],
                txns_per_id: 8,
                input_queue: None,
                label: "t".into(),
            },
            leaves,
        );
        let mut cy = 0;
        drive[0].set_now(cy);
        let mut c = Cmd::new(0, 0x1000 + 0x40, 0, 3); // leaf 1
        c.tag = 1;
        drive[0].ar.push(c);
        let mut reached = false;
        for _ in 0..60 {
            cy += 1;
            for d in &drive {
                d.set_now(cy);
            }
            for r in &recv {
                r.set_now(cy);
            }
            tree.roots[0].up_out.set_now(cy);
            for n in &mut tree.nodes {
                n.tick(cy);
            }
            assert!(
                !tree.roots[0].up_out.ar.can_pop(),
                "sibling traffic leaked to the root"
            );
            if recv[1].ar.can_pop() {
                recv[1].ar.pop();
                reached = true;
                break;
            }
        }
        assert!(reached);
    }
}
