//! Full-system case study (paper §4): Manticore, a 4096-core RISC-V
//! chiplet architecture for data-parallel floating-point computing; this
//! module builds one chiplet's 1024-core on-chip network from the §2
//! platform modules and reproduces the paper's §4 evaluation.

pub mod chiplet;
pub mod cluster;
pub mod network;
pub mod perf;
pub mod pod;
pub mod workload;

pub use chiplet::{Chiplet, ChipletCfg};
pub use cluster::{addr, core_net_cfg, dma_net_cfg, Cluster, ClusterHandle};
pub use network::{build_tree, NodeIo, Tree, TreeCfg};
pub use pod::{
    pod_determinism_fingerprint, podaddr, run_pod_collective, Pod, PodCfg, PodCollectiveResult,
    PodDie,
};
