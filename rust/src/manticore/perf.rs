//! Analytical performance and implementation models for the Manticore
//! case study: Table 2 (network implementation results) and Table 3
//! (NN-layer performance), cross-checked against simulation by the bench
//! harness (`benches/tab2_manticore.rs`, `benches/tab3_nn.rs`).

use crate::area::model::{area_timing, Module};
use crate::manticore::workload::ConvCfg;

// ---------------------------------------------------------------------------
// Table 3: NN layer performance
// ---------------------------------------------------------------------------

/// Machine parameters of one chiplet (paper §4).
pub struct Machine {
    pub clusters: usize,
    pub fpus_per_cluster: usize,
    pub freq_ghz: f64,
    pub fpu_util: f64,
    /// HBM bandwidth caps (GB/s): read channel and total.
    pub hbm_read_gbps: f64,
    pub hbm_total_gbps: f64,
}

impl Machine {
    pub fn manticore() -> Self {
        Machine {
            clusters: 128,
            fpus_per_cluster: 8,
            freq_ghz: 1.0,
            fpu_util: 0.8,
            hbm_read_gbps: 256.0,
            hbm_total_gbps: 262.0,
        }
    }

    /// Peak sustained dpflop/s (FMA = 2 flops) in Gdpflop/s.
    pub fn peak_gflops(&self) -> f64 {
        self.clusters as f64 * self.fpus_per_cluster as f64 * 2.0 * self.freq_ghz * self.fpu_util
            * 1.0e9
            / 1.0e9
    }
}

/// One column of Table 3.
#[derive(Debug, Clone)]
pub struct Tab3Row {
    pub label: &'static str,
    pub op_intensity: f64,
    pub hbm_gbps: f64,
    pub l3_gbps: f64,
    pub l2_gbps: f64,
    pub l1_gbps: f64,
    pub perf_gflops: f64,
}

/// Compute the four Table 3 columns analytically (paper §4.3).
pub fn table3(machine: &Machine, conv: ConvCfg, stack: usize, fc_batch: usize) -> Vec<Tab3Row> {
    let peak = machine.peak_gflops();
    let flops = conv.flops() as f64;

    // Per-variant HBM bytes for the conv layer (see python model.py for
    // the identical accounting, unit-tested against the paper's numbers).
    let conv_row = |label: &'static str, input_passes: f64, hbm_only_input: bool| -> Tab3Row {
        let l1_passes = (conv.k as f64 / stack as f64).ceil();
        let l1_bytes =
            l1_passes * conv.in_bytes() as f64 + conv.filter_bytes() as f64 + conv.out_bytes() as f64;
        let hbm_bytes = if hbm_only_input {
            input_passes * conv.in_bytes() as f64
        } else {
            input_passes * conv.in_bytes() as f64
                + conv.filter_bytes() as f64
                + conv.out_bytes() as f64
        };
        // Cluster-level operational intensity (compute per L1 byte).
        let oi_cluster = flops / l1_bytes;
        // HBM-level intensity decides compute- vs memory-bound.
        let oi_hbm = flops / hbm_bytes;
        let perf = (oi_hbm * machine.hbm_total_gbps).min(peak);
        let hbm_bw = perf / oi_hbm;
        let l1_bw = perf / oi_cluster;
        // L2: pipelined forwarding crosses an L1-quadrant boundary for 1 in
        // 4 hops (4 clusters per L1 quadrant); otherwise levels carry the
        // HBM stream.
        let (l2_bw, l3_bw) = if hbm_only_input {
            (l1_bw / 4.0, hbm_bw)
        } else {
            (hbm_bw, hbm_bw)
        };
        Tab3Row {
            label,
            op_intensity: oi_cluster,
            hbm_gbps: hbm_bw,
            l3_gbps: l3_bw,
            l2_gbps: l2_bw,
            l1_gbps: l1_bw,
            perf_gflops: perf,
        }
    };

    // Baseline: the whole input volume streams once per output slice, and
    // the cluster-level intensity equals the HBM-level one.
    let baseline = {
        let input_passes = conv.k as f64;
        let hbm_bytes = input_passes * conv.in_bytes() as f64
            + conv.filter_bytes() as f64
            + conv.out_bytes() as f64;
        let oi = flops / hbm_bytes;
        let perf = (oi * machine.hbm_total_gbps).min(peak);
        let bw = perf / oi;
        Tab3Row {
            label: "conv base",
            op_intensity: oi,
            hbm_gbps: bw,
            l3_gbps: bw,
            l2_gbps: bw,
            l1_gbps: bw,
            perf_gflops: perf,
        }
    };

    let stacked = conv_row("conv stacked", (conv.k as f64 / stack as f64).ceil(), false);
    let pipelined = conv_row("conv pipe'd", 1.0, true);

    // Fully connected: weights dominate; everything moves once.
    let fc = {
        let in_features = (conv.wi * conv.wi * conv.di) as f64;
        let fc_flops = 2.0 * fc_batch as f64 * in_features * conv.k as f64;
        let bytes = fc_batch as f64 * in_features * 8.0
            + in_features * conv.k as f64 * 8.0
            + fc_batch as f64 * conv.k as f64 * 8.0;
        let oi = fc_flops / bytes;
        let perf = (oi * machine.hbm_total_gbps).min(peak);
        let bw = perf / oi;
        Tab3Row {
            label: "fully conn.",
            op_intensity: oi,
            hbm_gbps: bw,
            l3_gbps: bw,
            l2_gbps: bw,
            l1_gbps: bw,
            perf_gflops: perf,
        }
    };

    vec![baseline, stacked, pipelined, fc]
}

pub fn render_table3(rows: &[Tab3Row]) -> String {
    let mut out = String::from(
        "Table 3 — Manticore NN-layer performance (analytical; GB/s, Gdpflop/s)\n",
    );
    out.push_str(&format!(
        "{:<14}{:>10}{:>10}{:>10}{:>10}{:>10}{:>12}\n",
        "layer", "OI [f/B]", "HBM BW", "L3 BW", "L2 BW", "L1 BW", "perf"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<14}{:>10.1}{:>10.0}{:>10.0}{:>10.0}{:>10.0}{:>12.0}\n",
            r.label, r.op_intensity, r.hbm_gbps, r.l3_gbps, r.l2_gbps, r.l1_gbps, r.perf_gflops
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Table 2: network implementation results
// ---------------------------------------------------------------------------

/// One row block of Table 2 (per network level).
#[derive(Debug, Clone)]
pub struct Tab2Level {
    pub name: &'static str,
    pub area_mm2_per_inst: f64,
    pub power_mw_per_inst: f64,
    pub insts_per_chiplet: usize,
}

/// Physical model: module standard-cell area from the §3 model
/// (data-width scaled); the *wire* share of each level is anchored to the
/// paper's published Table 2 per-instance areas — P&R routing-channel
/// area is floorplan-determined and cannot be derived from a gate-level
/// model (the paper: "the area of each network level is mainly determined
/// by the available routing channels"). The power *split* across levels
/// is genuinely modeled (cell power + wire load growing with the level
/// span) and calibrated only in its overall activity factor.
pub fn table2() -> Vec<Tab2Level> {
    // Cell area: per level, one 5x5 512-bit crosspoint (DMA net) + one 5x5
    // 64-bit crosspoint (core net) + pipeline registers.
    let xp64 = area_timing(Module::Crosspoint { s: 5, m: 5, i: 4 }).kge;
    // Datapath fraction ~65% scales with width (512/64 = 8x).
    let width_scale = |w: f64| 0.35 + 0.65 * (w / 64.0);
    let xp512 = xp64 * width_scale(512.0);
    let cells_kge = xp512 + xp64;
    let cell_mm2 = cells_kge * 1000.0 * crate::area::calib::UM2_PER_GE / 1e6;

    // Level spans in cluster widths (L1 quadrant = 2x2 clusters, ...).
    let spans = [2.1f64, 4.2, 8.4]; // mm, at ~1.05 mm cluster pitch
    // Wire-area anchors: paper per-instance areas minus our cell area.
    let paper_area = [0.41f64, 1.40, 2.99];
    let names = ["L1", "L2", "L3"];
    let insts = [32usize, 8, 2];
    // Overall activity calibrated so the chiplet network totals ~396 mW;
    // the per-level split follows the span-dependent wire load.
    let activity = 0.028;
    names
        .iter()
        .zip(spans.iter().zip(paper_area))
        .zip(insts)
        .map(|((name, (&span, parea)), ins)| {
            let wire_mm2 = (parea - cell_mm2).max(0.0);
            let area = cell_mm2 + wire_mm2;
            // Power: cell switching at 1 GHz plus wire capacitance that
            // grows with the span the level's bundles traverse.
            let power = cells_kge
                * crate::area::calib::MW_PER_KGE_GHZ
                * activity
                * (1.0 + span / 4.0);
            Tab2Level {
                name,
                area_mm2_per_inst: area,
                power_mw_per_inst: power,
                insts_per_chiplet: ins,
            }
        })
        .collect()
}

pub fn render_table2() -> String {
    let levels = table2();
    let mut out = String::from("Table 2 — Manticore network implementation results (modeled)\n");
    out.push_str(&format!(
        "{:<8}{:>16}{:>16}{:>8}{:>16}{:>16}\n",
        "level", "area/inst [mm2]", "power/inst [mW]", "#insts", "area/chip [mm2]", "power/chip [mW]"
    ));
    let mut tot_area = 0.0;
    let mut tot_power = 0.0;
    for l in &levels {
        let a = l.area_mm2_per_inst * l.insts_per_chiplet as f64;
        let p = l.power_mw_per_inst * l.insts_per_chiplet as f64;
        tot_area += a;
        tot_power += p;
        out.push_str(&format!(
            "{:<8}{:>16.2}{:>16.1}{:>8}{:>16.2}{:>16.1}\n",
            l.name, l.area_mm2_per_inst, l.power_mw_per_inst, l.insts_per_chiplet, a, p
        ));
    }
    out.push_str(&format!(
        "{:<8}{:>16}{:>16}{:>8}{:>16.2}{:>16.1}\n",
        "total", "-", "-", "-", tot_area, tot_power
    ));
    out.push_str(&format!(
        "paper:   L1 0.41 / L2 1.40 / L3 2.99 mm2 per inst; total 30.43 mm2, 396 mW\n\
         per-core network area: {:.0} um2 (paper: 29710 um2)\n",
        tot_area * 1e6 / 1024.0
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manticore::workload::CONV_PAPER;

    #[test]
    fn peak_performance_matches_paper() {
        let m = Machine::manticore();
        // 128 clusters x 8 FPUs x 2 flop x 80% = 1638.4 Gdpflop/s.
        assert!((m.peak_gflops() - 1638.4).abs() < 1.0);
    }

    #[test]
    fn table3_matches_paper_shape() {
        let rows = table3(&Machine::manticore(), CONV_PAPER, 8, 32);
        let base = &rows[0];
        let stacked = &rows[1];
        let piped = &rows[2];
        let fc = &rows[3];
        // Paper column 1: OI 2.2, HBM 262, perf 571.
        assert!((base.op_intensity - 2.2).abs() < 0.15, "{base:?}");
        assert!((base.perf_gflops - 571.0).abs() < 25.0, "{base:?}");
        // Column 2: OI 15.9, HBM ~98, perf 1638 (compute bound).
        assert!((stacked.op_intensity - 15.9).abs() < 0.5, "{stacked:?}");
        assert!((stacked.perf_gflops - 1638.0).abs() < 10.0);
        assert!((stacked.hbm_gbps - 98.0).abs() < 10.0, "{stacked:?}");
        // Column 3: HBM drops to ~6 GB/s at constant perf; L1 stays ~98.
        assert!(piped.hbm_gbps < 10.0, "{piped:?}");
        assert!((piped.perf_gflops - 1638.0).abs() < 10.0);
        assert!((piped.l1_gbps - 98.0).abs() < 10.0, "{piped:?}");
        assert!(piped.l2_gbps < 30.0 && piped.l2_gbps > 10.0, "{piped:?}");
        // Column 4: compute bound; paper reports OI 7.9 with weight-dominated
        // accounting (our strict in+w+out accounting gives ~6.4).
        assert!((5.5..9.0).contains(&fc.op_intensity), "{fc:?}");
        assert!(fc.perf_gflops > 1500.0);
    }

    #[test]
    fn table2_magnitudes() {
        let levels = table2();
        assert_eq!(levels.len(), 3);
        // Per-instance area must grow with the level span.
        assert!(levels[0].area_mm2_per_inst < levels[1].area_mm2_per_inst);
        assert!(levels[1].area_mm2_per_inst < levels[2].area_mm2_per_inst);
        // Within 2x of the paper's per-instance values.
        let paper = [0.41, 1.40, 2.99];
        for (l, p) in levels.iter().zip(paper) {
            let ratio = l.area_mm2_per_inst / p;
            assert!((0.5..2.0).contains(&ratio), "{}: {} vs paper {p}", l.name, l.area_mm2_per_inst);
        }
        // Total network power within 2x of 396 mW.
        let total: f64 =
            levels.iter().map(|l| l.power_mw_per_inst * l.insts_per_chiplet as f64).sum();
        assert!((200.0..800.0).contains(&total), "total power {total}");
    }

    #[test]
    fn render_functions_produce_tables() {
        let rows = table3(&Machine::manticore(), CONV_PAPER, 8, 32);
        let t3 = render_table3(&rows);
        assert!(t3.contains("conv stacked"));
        let t2 = render_table2();
        assert!(t2.contains("L1") && t2.contains("29710"));
    }
}
