//! NN-layer workloads for the Manticore case study (paper §4.3).
//!
//! Per-cluster scripts of DMA transfers interleaved with compute delays
//! drive the chiplet simulation the way the paper's RTL simulations were
//! driven: clusters stream tiles via DMA, compute at the FPU rate
//! (8 FPUs × 2 flop × 1 GHz × ~80% utilization), and either stream from
//! HBM (baseline/stacked variants) or from the previous cluster in the
//! processing pipeline (pipelined variant).

use std::collections::VecDeque;

use crate::collective::{self, Algo, CollCfg, CollOp};
use crate::errors::Result;
use crate::manticore::chiplet::Chiplet;
use crate::manticore::cluster::addr;
use crate::noc::dma::TransferReq;
use crate::sim::{Cycle, LatencyStats};

/// Convolutional-layer configuration (paper values: 32×32×128, K=128,
/// F=3, P=1, S=1). Mirrors python/compile/model.py::ConvCfg.
#[derive(Debug, Clone, Copy)]
pub struct ConvCfg {
    pub wi: usize,
    pub di: usize,
    pub k: usize,
    pub f: usize,
    pub p: usize,
    pub s: usize,
}

pub const CONV_PAPER: ConvCfg = ConvCfg { wi: 32, di: 128, k: 128, f: 3, p: 1, s: 1 };
/// Scaled configuration for simulation speed (same code path).
pub const CONV_SMALL: ConvCfg = ConvCfg { wi: 16, di: 32, k: 32, f: 3, p: 1, s: 1 };

impl ConvCfg {
    pub fn wo(&self) -> usize {
        (self.wi + 2 * self.p - self.f) / self.s + 1
    }

    pub fn flops(&self) -> u64 {
        2 * (self.wo() * self.wo() * self.k * self.f * self.f * self.di) as u64
    }

    /// Input volume bytes (fp64).
    pub fn in_bytes(&self) -> u64 {
        (self.wi * self.wi * self.di * 8) as u64
    }

    pub fn out_bytes(&self) -> u64 {
        (self.wo() * self.wo() * self.k * 8) as u64
    }

    pub fn filter_bytes(&self) -> u64 {
        (self.k * self.f * self.f * self.di * 8) as u64
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvVariant {
    Baseline,
    Stacked,
    Pipelined,
}

/// One step of a cluster's script.
pub enum Step {
    /// Submit a DMA on the given engine and wait for completion.
    Dma(usize, TransferReq),
    /// FPU compute for this many cycles.
    Compute(Cycle),
}

/// Cluster compute rate: 8 FPUs × 2 flop/cycle × 80% utilization
/// (the paper's sustained FPU utilization for real kernels).
pub const CLUSTER_FLOPS_PER_CYCLE: f64 = 8.0 * 2.0 * 0.8;

/// Build per-cluster conv-layer scripts over clusters `[0, n_clusters)`.
/// `stack` = output depth slices computed per input pass (1 = baseline
/// behaviour, 8 = the paper's stacked/pipelined configurations).
pub fn conv_scripts(
    cfg: ConvCfg,
    variant: ConvVariant,
    n_clusters: usize,
    stack: usize,
) -> Vec<VecDeque<Step>> {
    let slices_per_cluster = cfg.k.div_ceil(n_clusters).max(1);
    let in_slice_bytes = (cfg.wi * cfg.wi * 8) as u64; // one input depth slice
    let out_slice_bytes = (cfg.wo() * cfg.wo() * 8) as u64;
    let filt_slice_bytes = (cfg.f * cfg.f * cfg.di * 8) as u64;
    // FLOPs to produce one output depth slice.
    let flops_per_out_slice = 2 * (cfg.wo() * cfg.wo() * cfg.f * cfg.f * cfg.di) as u64;
    let compute_cycles = (flops_per_out_slice as f64 / CLUSTER_FLOPS_PER_CYCLE) as Cycle;

    let mut scripts = Vec::new();
    for c in 0..n_clusters {
        let mut steps = VecDeque::new();
        let l1 = addr::cluster_base(c) + 0x8000;
        let hbm_in = addr::HBM_BASE + 0x100_0000;
        let hbm_filt = addr::HBM_BASE + 0x200_0000;
        let hbm_out = addr::HBM_BASE + 0x300_0000 + ((c as u64) << 16);
        let mut out_slices_left = slices_per_cluster;
        while out_slices_left > 0 {
            let group = out_slices_left.min(stack);
            out_slices_left -= group;
            // Load filter parameters for this group of output slices.
            steps.push_back(Step::Dma(
                0,
                TransferReq::OneD {
                    src: hbm_filt,
                    dst: l1,
                    len: filt_slice_bytes * group as u64,
                },
            ));
            // Stream the input volume once per group: from HBM, or — in
            // the pipelined variant — from the previous cluster's L1.
            let src = match variant {
                ConvVariant::Pipelined if c > 0 => addr::cluster_base(c - 1) + 0x8000,
                _ => hbm_in,
            };
            // In chunks of 8 depth slices to bound the L1 footprint.
            let chunk = 8.min(cfg.di);
            let n_chunks = cfg.di.div_ceil(chunk);
            for ci in 0..n_chunks {
                steps.push_back(Step::Dma(
                    0,
                    TransferReq::OneD {
                        src: src + (ci as u64) * in_slice_bytes * chunk as u64,
                        dst: l1 + 0x4000,
                        len: in_slice_bytes * chunk as u64,
                    },
                ));
                // Compute on the chunk (proportional share of the group).
                steps.push_back(Step::Compute(
                    (compute_cycles * group as u64 * chunk as u64 / cfg.di as u64).max(1),
                ));
            }
            // Write the output slices back.
            steps.push_back(Step::Dma(
                1,
                TransferReq::OneD {
                    src: l1,
                    dst: hbm_out,
                    len: out_slice_bytes * group as u64,
                },
            ));
        }
        scripts.push(steps);
    }
    scripts
}

/// Batched fully-connected layer scripts (paper: W_I=32, D_I=128, D_O=128,
/// B=32): input depth slices parallelized over clusters, no inter-cluster
/// communication in the parallel region.
pub fn fc_scripts(
    b: usize,
    wi: usize,
    di: usize,
    do_: usize,
    n_clusters: usize,
) -> Vec<VecDeque<Step>> {
    let slices_per_cluster = di.div_ceil(n_clusters).max(1);
    let in_batch_slice = (b * wi * wi * 8) as u64; // batch of one depth slice
    let filt_pair = (wi * wi * 8) as u64; // params for one (in, out) pair
    let flops_per_pair = 2 * (b * wi * wi) as u64;
    let compute_cycles = (flops_per_pair as f64 / CLUSTER_FLOPS_PER_CYCLE) as Cycle;
    let mut scripts = Vec::new();
    for c in 0..n_clusters {
        let mut steps = VecDeque::new();
        let l1 = addr::cluster_base(c) + 0x8000;
        let hbm_in = addr::HBM_BASE + 0x400_0000 + ((c as u64) << 20);
        let hbm_filt = addr::HBM_BASE + 0x500_0000;
        let hbm_out = addr::HBM_BASE + 0x600_0000 + ((c as u64) << 12);
        for _slice in 0..slices_per_cluster {
            // Load the batch of this input depth slice.
            steps.push_back(Step::Dma(
                0,
                TransferReq::OneD { src: hbm_in, dst: l1, len: in_batch_slice },
            ));
            // Loop over output depth slices: load params, compute.
            for o in 0..do_ {
                steps.push_back(Step::Dma(
                    0,
                    TransferReq::OneD {
                        src: hbm_filt + (o as u64) * filt_pair,
                        dst: l1 + 0x4000,
                        len: filt_pair,
                    },
                ));
                steps.push_back(Step::Compute(compute_cycles.max(1)));
            }
        }
        // Reduce the private output volume (write once).
        steps.push_back(Step::Dma(
            1,
            TransferReq::OneD { src: l1, dst: hbm_out, len: (b * do_ * 8) as u64 },
        ));
        scripts.push(steps);
    }
    scripts
}

/// Submit the cross-section load: every cluster DMA-reads from and
/// DMA-writes to its neighbour (peer `c ^ 1`) with enough back-to-back
/// 16 KiB ping-pong blocks to saturate a `cycles`-long window (peak is
/// 64 B/cycle/engine). Shared by `noc manticore --workload xsection`
/// and `benches/tab2_manticore.rs` so both measure the same load.
pub fn xsection_submit(ch: &Chiplet, cycles: Cycle) {
    let n = ch.cfg.n_clusters();
    let block = 16 * 1024u64;
    let blocks = (cycles * 64).div_ceil(block) + 2;
    for c in 0..n {
        let peer = c ^ 1;
        for b in 0..blocks {
            let off = 0x8000 + (b % 2) * 0x2000;
            ch.submit_dma(
                c,
                0,
                TransferReq::OneD {
                    src: addr::cluster_base(peer) + off,
                    dst: addr::cluster_base(c) + off,
                    len: block,
                },
            );
            ch.submit_dma(
                c,
                1,
                TransferReq::OneD {
                    src: addr::cluster_base(c) + off + 0x4000,
                    dst: addr::cluster_base(peer) + off + 0x4000,
                    len: block,
                },
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Collective workloads (all-reduce / broadcast / ...): rank r = cluster r.
// ---------------------------------------------------------------------------

/// Per-rank link bandwidth of the DMA network: one 512-bit beat per
/// cycle. The unit of the ideal collective bounds — the tree's constant
/// link width (design property D2) gives every ring edge a full link, so
/// per-rank injection bandwidth is the binding constraint (the chiplet's
/// "bisection" is `n` such links).
pub const LINK_BYTES_PER_CYCLE: f64 = 64.0;

/// Lower bound on the cycles a collective over `n` ranks of `bytes`
/// needs at [`LINK_BYTES_PER_CYCLE`]: ring all-reduce moves
/// `2·(n-1)/n · bytes` per rank port, reduce-scatter / all-gather half
/// of that, and any broadcast at least the payload once.
pub fn collective_ideal_cycles(op: CollOp, algo: Algo, n: usize, bytes: u64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let b = bytes as f64;
    let frac = (n - 1) as f64 / n as f64;
    match (algo, op) {
        (Algo::Ring, CollOp::AllReduce) => 2.0 * frac * b / LINK_BYTES_PER_CYCLE,
        (Algo::Ring, CollOp::ReduceScatter | CollOp::AllGather) => frac * b / LINK_BYTES_PER_CYCLE,
        // Tree all-reduce sends the payload up and down every edge.
        (Algo::Tree, CollOp::AllReduce) => 2.0 * b / LINK_BYTES_PER_CYCLE,
        (_, _) => b / LINK_BYTES_PER_CYCLE,
    }
}

/// Address windows for a collective over all `n` clusters: rank r is
/// cluster r's full L1 window (the schedule builder lays out buffer,
/// scratch, and flag arenas inside; see `collective::schedule`).
pub fn collective_windows(n: usize) -> Vec<(u64, u64)> {
    (0..n).map(|i| (addr::cluster_base(i), addr::L1_SIZE)).collect()
}

/// Deterministic per-rank seed data (u64 element `j` of rank `r`).
fn collective_seed(r: usize, j: u64) -> u64 {
    (r as u64 + 1).wrapping_mul(0x9E37_79B9) ^ j
}

/// Result of running a collective workload end-to-end.
#[derive(Debug)]
pub struct CollectiveResult {
    pub cycles: Cycle,
    pub finished: bool,
    /// Buffers verified against the host-computed expectation.
    pub correct: bool,
    pub bytes: u64,
    /// Payload bytes per simulated cycle — the headline metric
    /// (`allreduce_bytes_per_cycle` in `BENCH_collective.json`).
    pub bytes_per_cycle: f64,
    /// Same, for an ideal fabric ([`collective_ideal_cycles`]).
    pub ideal_bytes_per_cycle: f64,
    /// Achieved / ideal (the bench gate asserts >= 0.5 for ring
    /// all-reduce).
    pub ideal_fraction: f64,
    pub cluster_dma_bytes: u64,
    /// Energy spent during the collective (telemetry delta; 0.0 when
    /// telemetry is off).
    pub energy_pj: f64,
    /// [`CollectiveResult::energy_pj`] per payload byte.
    pub energy_per_byte_pj: f64,
    /// Submit-to-drain latency of every DMA chain, merged across ranks.
    /// Always recorded (a histogram bump per chain), independent of the
    /// telemetry flag.
    pub chain_latency: LatencyStats,
}

/// Seed every rank's buffer, run the collective on the chiplet's
/// per-cluster orchestrators, and verify the result mathematically.
///
/// Uses the hierarchy-aware ring mapping
/// (`collective::hierarchical_order`) derived from the chiplet's
/// fanout — which, because the tree numbers clusters contiguously per
/// quadrant, is the identity permutation today; `benches/collective.rs`
/// records the delta against an explicit linear map to prove the two
/// coincide.
pub fn run_collective(
    ch: &mut Chiplet,
    op: CollOp,
    algo: Algo,
    bytes: u64,
    budget: Cycle,
) -> Result<CollectiveResult> {
    let order = collective::hierarchical_order(&ch.cfg.fanout);
    run_collective_with_order(ch, op, algo, bytes, budget, Some(order))
}

/// As [`run_collective`], with an explicit ring order (`None` = the
/// linear rank-r-equals-cluster-r map).
pub fn run_collective_with_order(
    ch: &mut Chiplet,
    op: CollOp,
    algo: Algo,
    bytes: u64,
    budget: Cycle,
    order: Option<Vec<usize>>,
) -> Result<CollectiveResult> {
    let n = ch.cfg.n_clusters();
    let windows = collective_windows(n);
    // Validated construction: a bad ring order or payload errors here,
    // before any DMA program or simulator state exists.
    let mut b = CollCfg::builder(op, algo, bytes);
    if let Some(o) = order {
        b = b.order(o);
    }
    let cfg = b.build(n)?;
    let mut built = collective::build(&cfg, &windows)?;
    let elems = bytes / 8;
    // Seed: all-reduce/reduce-scatter sum every rank's buffer; all-gather
    // circulates each rank's own chunk; broadcast propagates the root.
    for r in 0..n {
        let data: Vec<u8> = match op {
            CollOp::Broadcast if r != cfg.root => vec![0u8; bytes as usize],
            _ => (0..elems).flat_map(|j| collective_seed(r, j).to_le_bytes()).collect(),
        };
        ch.clusters[r].l1.borrow().banks.borrow_mut().poke(built.buf[r], &data);
    }
    let dma0 = ch.total_dma_bytes();
    let energy0 = ch.energy_report().total_fj();
    let start = ch.cycles;
    for (r, sched) in std::mem::take(&mut built.ranks).into_iter().enumerate() {
        ch.submit_collective(r, sched);
    }
    let finished = ch.run_until(budget, |c| c.all_collectives_done());
    let cycles = ch.cycles - start;
    let energy_pj = ch.energy_report().total_fj().saturating_sub(energy0) as f64 / 1000.0;
    // Cumulative over the chiplet's lifetime — the benches build a fresh
    // chiplet per measurement, so this is the collective's own
    // distribution there.
    let mut chain_latency = LatencyStats::new();
    for c in &ch.clusters {
        chain_latency.merge(&c.coll.borrow().chain_latency);
    }

    let sums: Vec<u64> = (0..elems)
        .map(|j| (0..n).fold(0u64, |a, r| a.wrapping_add(collective_seed(r, j))))
        .collect();
    let mut correct = finished;
    for r in 0..n {
        if !correct {
            break;
        }
        let got = ch.clusters[r].l1.borrow().banks.borrow().peek_vec(built.buf[r], bytes as usize);
        let words: Vec<u64> =
            got.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect();
        correct &= match op {
            CollOp::AllReduce => words == sums,
            CollOp::ReduceScatter => {
                // Rank r owns reduced chunk r; the rest is unspecified.
                let (off, len) = built.chunk_range(r);
                let lo = (off / 8) as usize;
                words[lo..lo + (len / 8) as usize] == sums[lo..lo + (len / 8) as usize]
            }
            CollOp::AllGather => (0..n).all(|c| {
                let (off, len) = built.chunk_range(c);
                let lo = off / 8;
                (0..len / 8).all(|j| words[(lo + j) as usize] == collective_seed(c, lo + j))
            }),
            CollOp::Broadcast => {
                (0..elems).all(|j| words[j as usize] == collective_seed(cfg.root, j))
            }
        };
    }
    let ideal = collective_ideal_cycles(op, algo, n, bytes).max(1.0);
    let bpc = bytes as f64 / cycles.max(1) as f64;
    let ideal_bpc = bytes as f64 / ideal;
    Ok(CollectiveResult {
        cycles,
        finished,
        correct,
        bytes,
        bytes_per_cycle: bpc,
        ideal_bytes_per_cycle: ideal_bpc,
        ideal_fraction: bpc / ideal_bpc,
        cluster_dma_bytes: ch.total_dma_bytes() - dma0,
        energy_pj,
        energy_per_byte_pj: energy_pj / bytes.max(1) as f64,
        chain_latency,
    })
}

struct ScriptState {
    steps: VecDeque<Step>,
    waiting: Option<(usize, u64)>,
    compute_until: Cycle,
}

impl ScriptState {
    fn done(&self, cy: Cycle) -> bool {
        self.steps.is_empty() && self.waiting.is_none() && cy >= self.compute_until
    }

    fn advance(&mut self, ch: &Chiplet, cluster: usize, cy: Cycle) {
        if let Some((engine, h)) = self.waiting {
            if ch.dma_done(cluster, engine, h) {
                self.waiting = None;
            } else {
                return;
            }
        }
        if cy < self.compute_until {
            return;
        }
        match self.steps.pop_front() {
            None => {}
            Some(Step::Dma(engine, req)) => {
                let h = ch.submit_dma(cluster, engine, req);
                self.waiting = Some((engine, h));
            }
            Some(Step::Compute(cycles)) => {
                self.compute_until = cy + cycles;
            }
        }
    }
}

/// Result of running a scripted workload.
#[derive(Debug)]
pub struct WorkloadResult {
    pub cycles: Cycle,
    pub finished: bool,
    pub hbm_bytes: u64,
    pub cluster_dma_bytes: u64,
    /// Data bytes across DMA-tree uplinks, bottom-up per level.
    pub level_bytes: Vec<u64>,
    /// Energy spent during the workload (telemetry delta; 0.0 when
    /// telemetry is off).
    pub energy_pj: f64,
}

impl WorkloadResult {
    /// GB/s at 1 GHz for a byte counter over the run.
    pub fn gbps(&self, bytes: u64) -> f64 {
        bytes as f64 / self.cycles.max(1) as f64
    }
}

/// Run per-cluster scripts on the chiplet; cluster `i` runs `scripts[i]`.
pub fn run_scripts(
    ch: &mut Chiplet,
    scripts: Vec<VecDeque<Step>>,
    budget: Cycle,
) -> WorkloadResult {
    let hbm0 = ch.hbm_bytes();
    let dma0 = ch.total_dma_bytes();
    let lvl0 = ch.dma_level_bytes();
    let energy0 = ch.energy_report().total_fj();
    let mut state: Vec<ScriptState> = scripts
        .into_iter()
        .map(|steps| ScriptState { steps, waiting: None, compute_until: 0 })
        .collect();
    let start = ch.cycles;
    let mut finished = false;
    while ch.cycles - start < budget {
        ch.step();
        let cy = ch.cycles;
        let mut all_done = true;
        for (c, s) in state.iter_mut().enumerate() {
            s.advance(ch, c, cy);
            all_done &= s.done(cy);
        }
        if all_done {
            finished = true;
            break;
        }
    }
    let lvl1 = ch.dma_level_bytes();
    WorkloadResult {
        cycles: ch.cycles - start,
        finished,
        hbm_bytes: ch.hbm_bytes() - hbm0,
        cluster_dma_bytes: ch.total_dma_bytes() - dma0,
        level_bytes: lvl1.iter().zip(lvl0).map(|(a, b)| a - b).collect(),
        energy_pj: ch.energy_report().total_fj().saturating_sub(energy0) as f64 / 1000.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manticore::chiplet::ChipletCfg;
    use crate::sim::EngineOpts;

    #[test]
    fn conv_cfg_paper_numbers() {
        let c = CONV_PAPER;
        assert_eq!(c.wo(), 32);
        assert_eq!(c.flops(), 301_989_888);
        assert_eq!(c.in_bytes(), 1_048_576);
    }

    fn hbm_script_bytes(scripts: &[VecDeque<Step>]) -> u64 {
        scripts
            .iter()
            .flatten()
            .map(|s| match s {
                Step::Dma(_, TransferReq::OneD { len, src, .. }) if *src >= addr::HBM_BASE => *len,
                _ => 0,
            })
            .sum()
    }

    #[test]
    fn baseline_streams_more_hbm_than_stacked() {
        let cfg = ConvCfg { wi: 8, di: 16, k: 8, f: 3, p: 1, s: 1 };
        let base = hbm_script_bytes(&conv_scripts(cfg, ConvVariant::Baseline, 4, 1));
        let stacked = hbm_script_bytes(&conv_scripts(cfg, ConvVariant::Stacked, 4, 8));
        assert!(base > stacked, "baseline {base} must exceed stacked {stacked}");
    }

    #[test]
    fn pipelined_reads_from_neighbours() {
        let cfg = ConvCfg { wi: 8, di: 16, k: 8, f: 3, p: 1, s: 1 };
        let scripts = conv_scripts(cfg, ConvVariant::Pipelined, 4, 8);
        for (c, s) in scripts.iter().enumerate().skip(1) {
            let has_local = s.iter().any(|st| {
                matches!(st, Step::Dma(_, TransferReq::OneD { src, .. })
                    if *src < addr::HBM_BASE)
            });
            assert!(has_local, "cluster {c} must read from its neighbour");
        }
    }

    #[test]
    fn small_conv_runs_on_small_chiplet() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        let cfg = ConvCfg { wi: 8, di: 8, k: 8, f: 3, p: 1, s: 1 };
        let scripts = conv_scripts(cfg, ConvVariant::Stacked, 4, 4);
        let res = run_scripts(&mut ch, scripts, 2_000_000);
        assert!(res.finished, "conv workload must finish ({} cycles)", res.cycles);
        assert!(res.hbm_bytes > 0);
        assert!(res.cluster_dma_bytes > 0);
    }

    #[test]
    fn small_fc_runs_on_small_chiplet() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        let scripts = fc_scripts(4, 8, 8, 8, 4);
        let res = run_scripts(&mut ch, scripts, 2_000_000);
        assert!(res.finished, "fc workload must finish ({} cycles)", res.cycles);
        assert!(res.hbm_bytes > 0);
    }

    #[test]
    fn ring_allreduce_on_small_chiplet_is_correct() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        let res =
            run_collective(&mut ch, CollOp::AllReduce, Algo::Ring, 16 * 1024, 500_000).unwrap();
        assert!(res.finished, "all-reduce must finish");
        assert!(res.correct, "all-reduce buffers must hold the exact sums");
        assert!(res.cluster_dma_bytes >= res.bytes, "data must actually cross the ports");
        assert!(res.ideal_fraction > 0.0 && res.ideal_fraction <= 1.0 + 1e-9);
    }

    #[test]
    fn collective_reports_energy_and_chain_percentiles() {
        let mut cfg = ChipletCfg::small();
        cfg.engine.telemetry = true;
        let mut ch = Chiplet::new(cfg);
        let res =
            run_collective(&mut ch, CollOp::AllReduce, Algo::Ring, 4096, 500_000).unwrap();
        assert!(res.finished && res.correct);
        assert!(res.energy_pj > 0.0, "telemetry on: the collective burns energy");
        assert!(res.energy_per_byte_pj > 0.0);
        assert!(res.chain_latency.count() > 0, "every Send chain is recorded");
        assert!(res.chain_latency.percentile(50.0) <= res.chain_latency.percentile(99.0));

        // Telemetry off (default): zero energy, but chain latency is an
        // always-on histogram.
        let mut off = Chiplet::new(ChipletCfg::small());
        let r2 = run_collective(&mut off, CollOp::AllReduce, Algo::Ring, 4096, 500_000).unwrap();
        assert_eq!(r2.energy_pj, 0.0);
        assert!(r2.chain_latency.count() > 0);
    }

    #[test]
    fn reduce_scatter_and_allgather_on_small_chiplet() {
        for op in [CollOp::ReduceScatter, CollOp::AllGather] {
            let mut ch = Chiplet::new(ChipletCfg::small());
            let res = run_collective(&mut ch, op, Algo::Ring, 8 * 1024, 500_000).unwrap();
            assert!(res.finished && res.correct, "{op:?} must finish correctly");
        }
    }

    #[test]
    fn broadcast_ring_and_tree_on_small_chiplet() {
        for algo in [Algo::Ring, Algo::Tree] {
            let mut ch = Chiplet::new(ChipletCfg::small());
            let res = run_collective(&mut ch, CollOp::Broadcast, algo, 8 * 1024, 500_000).unwrap();
            assert!(res.finished && res.correct, "{algo:?} broadcast must finish correctly");
        }
    }

    #[test]
    fn tree_allreduce_on_small_chiplet() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        let res =
            run_collective(&mut ch, CollOp::AllReduce, Algo::Tree, 8 * 1024, 500_000).unwrap();
        assert!(res.finished && res.correct);
    }

    #[test]
    fn sharded_ring_allreduce_is_correct() {
        let mut cfg = ChipletCfg::small();
        cfg.engine = EngineOpts::sharded(2, 8);
        let mut ch = Chiplet::new(cfg);
        let res =
            run_collective(&mut ch, CollOp::AllReduce, Algo::Ring, 16 * 1024, 1_000_000).unwrap();
        assert!(res.finished && res.correct, "all-reduce must survive the epoch cuts");
    }

    /// Run one collective with an explicit ring order on a fresh small
    /// chiplet and return the verified result plus the fingerprint.
    fn ordered_run(op: CollOp, order: Option<Vec<usize>>) -> (Cycle, bool, String) {
        use crate::manticore::chiplet::determinism_fingerprint;
        let mut ch = Chiplet::new(ChipletCfg::small());
        let r = run_collective_with_order(&mut ch, op, Algo::Ring, 4096, 500_000, order).unwrap();
        assert!(r.finished, "{op:?} must finish");
        (r.cycles, r.correct, determinism_fingerprint(&ch))
    }

    #[test]
    fn hierarchical_ring_map_is_noop_on_contiguous_clusters() {
        // The tree numbers clusters contiguously per quadrant, so the
        // hierarchy-aware order must equal the identity and leave the
        // all-reduce result *and* the determinism fingerprint (cycles,
        // per-level traffic, per-cluster counters) bit-identical to the
        // linear rank-r-equals-cluster-r map.
        let order = collective::hierarchical_order(&[2, 2]);
        assert_eq!(order, vec![0, 1, 2, 3]);
        let linear = ordered_run(CollOp::AllReduce, None);
        let hier = ordered_run(CollOp::AllReduce, Some(order));
        assert!(linear.1, "all-reduce must be exact");
        assert_eq!(linear, hier, "hierarchy-aware map must be a no-op today");
    }

    #[test]
    fn permuted_ring_order_still_exact_on_chiplet() {
        // A genuinely shuffled ring order through the real NoC: every
        // transfer targets different neighbours, yet the math and the
        // reduce-scatter ownership contract must hold.
        for op in [CollOp::AllReduce, CollOp::ReduceScatter] {
            let (_, correct, _) = ordered_run(op, Some(vec![2, 0, 3, 1]));
            assert!(correct, "{op:?} with permuted order must be exact");
        }
    }

    #[test]
    fn collective_rejects_oversized_payload() {
        let mut ch = Chiplet::new(ChipletCfg::small());
        // 128 KiB payload + scratch cannot fit the 128 KiB L1.
        assert!(run_collective(&mut ch, CollOp::AllReduce, Algo::Ring, addr::L1_SIZE, 1).is_err());
    }

    #[test]
    fn pipelined_uses_less_hbm_in_simulation() {
        let cfg = ConvCfg { wi: 8, di: 16, k: 16, f: 3, p: 1, s: 1 };
        let run = |variant, stack| {
            let mut ch = Chiplet::new(ChipletCfg::small());
            let scripts = conv_scripts(cfg, variant, 4, stack);
            run_scripts(&mut ch, scripts, 4_000_000)
        };
        let stacked = run(ConvVariant::Stacked, 8);
        let piped = run(ConvVariant::Pipelined, 8);
        assert!(stacked.finished && piped.finished);
        assert!(
            piped.hbm_bytes < stacked.hbm_bytes,
            "pipelined ({}) must save HBM traffic vs stacked ({})",
            piped.hbm_bytes,
            stacked.hbm_bytes
        );
    }
}
