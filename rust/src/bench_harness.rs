//! Measurement harness for `benches/*.rs` (criterion is unavailable
//! offline). Provides wall-clock timing with warmup + repetitions,
//! tabular reporting, a CI smoke mode (`NOC_BENCH_QUICK=1`) that shrinks
//! iteration counts, and machine-readable `BENCH_<name>.json` result
//! files so CI can archive and track the perf trajectory.

use std::path::PathBuf;
use std::time::Instant;

use crate::coordinator::report::Json;

/// True when `NOC_BENCH_QUICK=1`: benches shrink their iteration counts so
/// the whole suite finishes in well under a minute (the CI smoke job).
pub fn quick() -> bool {
    std::env::var("NOC_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Pick `full` normally, `quick_n` in smoke mode.
pub fn iters(full: u64, quick_n: u64) -> u64 {
    if quick() {
        quick_n
    } else {
        full
    }
}

/// Timing summary over repetitions.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub reps: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Work units per second (caller-defined unit, e.g. cycles or beats).
    pub throughput: Option<f64>,
}

/// Time `f` for `reps` repetitions after one warmup run. `work` is the
/// number of work units executed per repetition (for throughput).
pub fn bench(name: &str, reps: usize, work: Option<u64>, mut f: impl FnMut()) -> Timing {
    f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    Timing {
        name: name.to_string(),
        reps,
        mean_s: mean,
        min_s: min,
        max_s: max,
        throughput: work.map(|w| w as f64 / mean),
    }
}

impl Timing {
    pub fn row(&self) -> String {
        let tp = self
            .throughput
            .map(|t| {
                if t > 1e6 {
                    format!("{:>10.2} M/s", t / 1e6)
                } else {
                    format!("{:>10.1} k/s", t / 1e3)
                }
            })
            .unwrap_or_else(|| format!("{:>12}", "-"));
        format!(
            "{:<40} {:>10.3} ms {:>10.3} ms {tp}",
            self.name,
            self.mean_s * 1e3,
            self.min_s * 1e3
        )
    }

    fn to_json(&self) -> Json {
        let mut obj = vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("reps".into(), Json::Num(self.reps as f64)),
            ("mean_s".into(), Json::Num(self.mean_s)),
            ("min_s".into(), Json::Num(self.min_s)),
            ("max_s".into(), Json::Num(self.max_s)),
        ];
        if let Some(t) = self.throughput {
            obj.push(("throughput_per_s".into(), Json::Num(t)));
        }
        Json::Obj(obj)
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
    println!("{:<40} {:>13} {:>13} {:>12}", "case", "mean", "min", "throughput");
}

/// Machine-readable result accumulator for one bench binary. `finish`
/// writes `BENCH_<name>.json` (to `$NOC_BENCH_DIR` or the working
/// directory) so CI can archive the numbers and track them over time.
pub struct Report {
    name: String,
    metrics: Vec<(String, f64)>,
    timings: Vec<Timing>,
}

impl Report {
    pub fn new(name: impl Into<String>) -> Self {
        Report { name: name.into(), metrics: Vec::new(), timings: Vec::new() }
    }

    /// Record a scalar result (throughput, ratio, cycle count, ...).
    pub fn metric(&mut self, key: impl Into<String>, value: f64) {
        self.metrics.push((key.into(), value));
    }

    /// Record a wall-clock timing (and return it for printing).
    pub fn timing(&mut self, t: Timing) -> Timing {
        self.timings.push(t.clone());
        t
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("bench".into(), Json::Str(self.name.clone())),
            ("quick".into(), Json::Bool(quick())),
            (
                "metrics".into(),
                Json::Obj(self.metrics.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect()),
            ),
            ("timings".into(), Json::Arr(self.timings.iter().map(|t| t.to_json()).collect())),
        ])
    }

    pub fn path(&self) -> PathBuf {
        let dir = std::env::var("NOC_BENCH_DIR").unwrap_or_else(|_| ".".into());
        PathBuf::from(dir).join(format!("BENCH_{}.json", self.name))
    }

    /// Write the JSON file; prints where it went (or why it could not).
    pub fn finish(&self) {
        let path = self.path();
        match std::fs::write(&path, self.to_json().render() + "\n") {
            Ok(()) => println!("\nwrote {}", path.display()),
            Err(e) => eprintln!("\nwarning: could not write {}: {e}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench("spin", 3, Some(1000), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(t.reps, 3);
        assert!(t.mean_s >= 0.0 && t.min_s <= t.mean_s && t.mean_s <= t.max_s);
        assert!(t.throughput.unwrap() > 0.0);
        assert!(t.row().contains("spin"));
    }

    #[test]
    fn report_renders_json() {
        let mut r = Report::new("unit_test");
        r.metric("cycles_per_sec", 1.5e6);
        r.timing(Timing {
            name: "case".into(),
            reps: 1,
            mean_s: 0.5,
            min_s: 0.5,
            max_s: 0.5,
            throughput: Some(2.0),
        });
        let j = r.to_json().render();
        assert!(j.contains("\"bench\":\"unit_test\""), "{j}");
        assert!(j.contains("\"cycles_per_sec\":1500000"), "{j}");
        assert!(j.contains("\"throughput_per_s\":2"), "{j}");
        assert!(r.path().to_string_lossy().contains("BENCH_unit_test.json"));
    }

    #[test]
    fn iters_scales_in_quick_mode_only() {
        // Not set in the test environment: full count wins.
        if !quick() {
            assert_eq!(iters(1000, 10), 1000);
        } else {
            assert_eq!(iters(1000, 10), 10);
        }
    }
}
