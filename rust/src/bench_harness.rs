//! Measurement harness for `benches/*.rs` (criterion is unavailable
//! offline). Provides wall-clock timing with warmup + repetitions and
//! tabular reporting, plus helpers shared by the figure/table benches.

use std::time::Instant;

/// Timing summary over repetitions.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub reps: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    /// Work units per second (caller-defined unit, e.g. cycles or beats).
    pub throughput: Option<f64>,
}

/// Time `f` for `reps` repetitions after one warmup run. `work` is the
/// number of work units executed per repetition (for throughput).
pub fn bench(name: &str, reps: usize, work: Option<u64>, mut f: impl FnMut()) -> Timing {
    f(); // warmup
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    Timing {
        name: name.to_string(),
        reps,
        mean_s: mean,
        min_s: min,
        max_s: max,
        throughput: work.map(|w| w as f64 / mean),
    }
}

impl Timing {
    pub fn row(&self) -> String {
        let tp = self
            .throughput
            .map(|t| {
                if t > 1e6 {
                    format!("{:>10.2} M/s", t / 1e6)
                } else {
                    format!("{:>10.1} k/s", t / 1e3)
                }
            })
            .unwrap_or_else(|| format!("{:>12}", "-"));
        format!(
            "{:<40} {:>10.3} ms {:>10.3} ms {tp}",
            self.name,
            self.mean_s * 1e3,
            self.min_s * 1e3
        )
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
    println!("{:<40} {:>13} {:>13} {:>12}", "case", "mean", "min", "throughput");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let t = bench("spin", 3, Some(1000), || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert_eq!(t.reps, 3);
        assert!(t.mean_s >= 0.0 && t.min_s <= t.mean_s && t.mean_s <= t.max_s);
        assert!(t.throughput.unwrap() > 0.0);
        assert!(t.row().contains("spin"));
    }
}
