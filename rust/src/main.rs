//! `noc` — the platform CLI (leader entrypoint).
//!
//! Subcommands:
//!   figures                 regenerate the paper's Figs 13–21 series
//!   tables [--tab N]        regenerate Tables 1–4
//!   simulate --config F     run a configured topology (TOML subset)
//!   manticore [...]         run the §4 case-study simulations
//!   multichip [...]         multi-chiplet pod collectives over D2D links
//!   e2e [...]               PJRT compute + network co-simulation
//!
//! Argument parsing is hand-rolled (clap is unavailable offline).

use std::collections::HashMap;

use noc::errors::{Context, Result};
use noc::{bail, ensure};

use noc::collective::{Algo, CollOp};
use noc::manticore::chiplet::{Chiplet, ChipletCfg};
use noc::manticore::perf::{render_table2, render_table3, table3, Machine};
use noc::manticore::workload::{
    conv_scripts, fc_scripts, run_collective, run_scripts, xsection_submit, ConvVariant,
    CONV_SMALL,
};

/// Drain telemetry after a run: write the Chrome `trace_event` JSON when
/// `--trace` named a file, then print the energy and (when available)
/// link-utilization reports. Everything here is stamped with simulated
/// cycles, so the outputs are bit-identical across `--threads N` and the
/// event/full-scan engine modes and can be diffed between runs.
fn emit_telemetry(
    flags: &HashMap<String, String>,
    (events, dropped): (Vec<noc::telemetry::TraceEvent>, u64),
    energy: noc::telemetry::EnergyReport,
    links: Option<noc::coordinator::Json>,
) -> Result<()> {
    if let Some(path) = flags.get("trace").filter(|p| p.as_str() != "true") {
        std::fs::write(path, noc::telemetry::chrome_trace_json(&events, dropped))
            .with_context(|| format!("writing trace to {path}"))?;
        println!("trace: {} events -> {path} ({dropped} dropped)", events.len());
    }
    println!("energy: {}", energy.render());
    if let Some(l) = links {
        println!("links: {}", l.render());
    }
    Ok(())
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(k) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(k.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(k.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn cmd_figures(flags: &HashMap<String, String>) -> Result<()> {
    let filter = flags.get("fig").map(|s| s.as_str());
    for s in noc::area::all_figures() {
        if let Some(f) = filter {
            if !s.figure.to_lowercase().contains(&f.to_lowercase()) {
                continue;
            }
        }
        println!("{}", s.render());
    }
    Ok(())
}

fn cmd_tables(flags: &HashMap<String, String>) -> Result<()> {
    let which = flags.get("tab").map(|s| s.as_str()).unwrap_or("all");
    if which == "1" || which == "all" {
        println!("{}", noc::area::table1());
    }
    if which == "2" || which == "all" {
        println!("{}", render_table2());
    }
    if which == "3" || which == "all" {
        let rows = table3(&Machine::manticore(), noc::manticore::workload::CONV_PAPER, 8, 32);
        println!("{}", render_table3(&rows));
    }
    if which == "4" || which == "all" {
        println!("{}", noc::area::table4());
    }
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<()> {
    let path = flags.get("config").context("--config <file> required")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let doc = noc::coordinator::parse(&text)?;
    // A `[topology]` table selects the recursive template grammar
    // (`coordinator::topology`); flat `[[master]]` / `[[slave]]` configs
    // keep the single-crossbar builder. Both embed the same `EngineOpts`,
    // so the `--threads` / `--epoch` / `--full-scan` overrides are one
    // code path (unset threads auto-pick the host core count; `--threads
    // 0` stays the explicit single-arena mode).
    let (mut cycles, mut sys) = if doc.table("topology").is_some() {
        let mut cfg = noc::coordinator::TopoCfg::from_doc(&doc)?;
        cfg.engine.apply_cli(flags, true)?;
        (cfg.cycles, cfg.build()?)
    } else {
        let mut cfg = noc::coordinator::SimCfg::from_doc(&doc)?;
        cfg.engine.apply_cli(flags, true)?;
        (cfg.cycles, noc::coordinator::System::build(&cfg)?)
    };
    if let Some(c) = flags.get("cycles") {
        cycles = c.parse().context("--cycles must be a non-negative integer")?;
    }
    let done = sys.run(cycles);
    if flags.contains_key("fingerprint") {
        // Canonical run digest for scripted determinism checks.
        println!("{}", noc::coordinator::determinism_fingerprint(&sys));
    } else if flags.contains_key("json") {
        println!("{}", noc::coordinator::run_report(&sys).render());
    } else {
        println!("{}", noc::coordinator::run_summary(&sys));
        if !done {
            println!("warning: traffic did not finish within {cycles} cycles");
        }
    }
    if sys.telemetry_enabled() {
        emit_telemetry(flags, sys.take_trace_events(), sys.energy_report(), None)?;
    }
    let v = sys.check_protocol();
    if !v.is_empty() {
        bail!("{} protocol violations: {:#?}", v.len(), &v[..v.len().min(5)]);
    }
    Ok(())
}

fn chiplet_from_flags(flags: &HashMap<String, String>, auto_threads: bool) -> Result<ChipletCfg> {
    let mut cfg = match flags.get("size").map(|s| s.as_str()).unwrap_or("small") {
        "full" => ChipletCfg::full(),
        "medium" => ChipletCfg { fanout: vec![4, 4], ..ChipletCfg::full() },
        _ => ChipletCfg::small(),
    };
    // Only batched workloads auto-pick the host core count when
    // --threads is unset (bit-identical for any worker count >= 1, so
    // this never changes results across hosts). Workloads whose numbers
    // are compared against the paper's single-arena timing model — the
    // latency probe and the per-cycle conv/fc scripts, which gain no
    // parallelism from sharding anyway — stay single-arena unless asked.
    cfg.engine.apply_cli(flags, auto_threads)?;
    Ok(cfg)
}

/// Cross-section bandwidth: every cluster DMA-reads from the cluster
/// "across the top" while DMA-writing to it — all links saturated.
/// Drain a chiplet's telemetry artifacts (no-op when the layer is off).
fn drain_chiplet_telemetry(ch: &mut Chiplet, flags: &HashMap<String, String>) -> Result<()> {
    if ch.telemetry_enabled() {
        emit_telemetry(flags, ch.take_trace_events(), ch.energy_report(), Some(ch.link_report()))?;
    }
    Ok(())
}

fn manticore_xsection(cfg: ChipletCfg, cycles: u64) -> Result<Chiplet> {
    let n = cfg.n_clusters();
    let mut ch = Chiplet::new(cfg);
    // Enough back-to-back blocks per engine to saturate the whole window:
    // peak is 64 B/cycle/engine. Peers are neighbours within the same L1
    // quadrant: the tree's constant link width (design property D2) means
    // the paper's 32 TB/s "cross-sectional" figure is the aggregate
    // bandwidth terminated at the cluster ports, not an all-to-all
    // bisection across the root (which a tree does not provide).
    xsection_submit(&ch, cycles);
    // Warmup, then measure over the window.
    ch.run(500);
    let bytes0 = ch.total_dma_bytes();
    let t0 = std::time::Instant::now();
    ch.run(cycles);
    let wall = t0.elapsed();
    let bytes = ch.total_dma_bytes() - bytes0;
    let bw = bytes as f64 / cycles as f64; // B/cycle = GB/s at 1 GHz
    let peak = n as f64 * 2.0 * 64.0;
    println!("cross-section: {n} clusters, {cycles} cycles measured");
    println!(
        "  cluster master-port data: {bytes} B ({bw:.1} GB/s at 1 GHz, {:.0}% of {:.0} GB/s peak)",
        100.0 * bw / peak,
        peak
    );
    println!(
        "  scaled to 128 clusters incl. slave-port terminations: {:.1} TB/s (paper: 32 TB/s)",
        bw * (128.0 / n as f64) * 2.0 / 1000.0
    );
    println!(
        "  sim wall time: {:.2}s ({:.1} kcycles/s)",
        wall.as_secs_f64(),
        cycles as f64 / wall.as_secs_f64() / 1000.0
    );
    Ok(ch)
}

/// Core-to-core round-trip latency: single-beat reads from cluster 0 to
/// the farthest cluster on an otherwise idle network.
fn manticore_latency(cfg: ChipletCfg) -> Result<Chiplet> {
    let n = cfg.n_clusters();
    let mut ch = Chiplet::new(cfg);
    use noc::manticore::cluster::addr;
    use noc::traffic::gen::{AddrPattern, RwGenCfg};
    ch.clusters[0].cores.borrow_mut().set_cfg(RwGenCfg {
        pattern: AddrPattern::Uniform { base: addr::cluster_base(n - 1), span: 0x1000 },
        p_read: 1.0,
        total: Some(32),
        max_outstanding: 1, // unloaded latency
        verify: false,
        seed: 3,
        ..Default::default()
    });
    let ok = ch.run_until(1_000_000, |c| c.clusters[0].cores.borrow().done());
    ensure!(ok, "latency probe did not finish");
    let stats = ch.clusters[0].cores.borrow().stats.clone();
    println!("round-trip latency cluster 0 -> cluster {} (core network):", n - 1);
    println!(
        "  mean {:.1} cycles, min {}, max {} (paper headline: 24 ns @ 1 GHz)",
        stats.read_latency.mean(),
        stats.read_latency.min(),
        stats.read_latency.max()
    );
    println!(
        "  p50 {} / p99 {} cycles",
        stats.read_latency.percentile(50.0),
        stats.read_latency.percentile(99.0)
    );
    Ok(ch)
}

/// DMA-driven collective over all clusters: seed, run, verify, and report
/// achieved vs ideal bandwidth (`--workload allreduce|broadcast`,
/// `--collective ring|tree`, `--bytes N`).
fn manticore_collective(
    cfg: ChipletCfg,
    op: CollOp,
    flags: &HashMap<String, String>,
) -> Result<()> {
    let algo = match flags.get("collective").map(|s| s.as_str()).unwrap_or("ring") {
        "ring" => Algo::Ring,
        "tree" => Algo::Tree,
        a => bail!("unknown collective algorithm: {a} (ring|tree)"),
    };
    let bytes: u64 = flags.get("bytes").map(|s| s.parse()).transpose()?.unwrap_or(32 * 1024);
    let n = cfg.n_clusters();
    let mut ch = Chiplet::new(cfg);
    let res = run_collective(&mut ch, op, algo, bytes, 10_000_000)?;
    ensure!(res.finished, "collective did not finish within the cycle budget");
    ensure!(res.correct, "collective result failed verification");
    println!("{op:?} ({algo:?}) over {n} clusters, {bytes} B payload: {} cycles", res.cycles);
    println!(
        "  {:.2} B/cycle achieved vs {:.2} B/cycle ideal ({:.0}% of the \
         2·(N−1)/N·bytes / link-bandwidth bound)",
        res.bytes_per_cycle,
        res.ideal_bytes_per_cycle,
        100.0 * res.ideal_fraction
    );
    println!("  cluster-port traffic: {} B, result verified on every rank", res.cluster_dma_bytes);
    println!(
        "  DMA chain latency: p50 {} / p99 {} cycles over {} chains",
        res.chain_latency.percentile(50.0),
        res.chain_latency.percentile(99.0),
        res.chain_latency.count()
    );
    if ch.telemetry_enabled() {
        println!(
            "  energy: {:.1} pJ for the op ({:.4} pJ/B)",
            res.energy_pj, res.energy_per_byte_pj
        );
    }
    drain_chiplet_telemetry(&mut ch, flags)?;
    Ok(())
}

fn cmd_manticore(flags: &HashMap<String, String>) -> Result<()> {
    let workload = flags.get("workload").map(|s| s.as_str()).unwrap_or("xsection").to_string();
    // Only the batched workloads auto-engage the sharded engine; see
    // `chiplet_from_flags`.
    let batched = matches!(workload.as_str(), "xsection" | "allreduce" | "broadcast");
    let cfg = chiplet_from_flags(flags, batched)?;
    let cycles: u64 = flags.get("cycles").map(|s| s.parse()).transpose()?.unwrap_or(20_000);
    match workload.as_str() {
        "xsection" => {
            let mut ch = manticore_xsection(cfg, cycles)?;
            drain_chiplet_telemetry(&mut ch, flags)?;
        }
        "latency" => {
            let mut ch = manticore_latency(cfg)?;
            drain_chiplet_telemetry(&mut ch, flags)?;
        }
        "allreduce" => manticore_collective(cfg, CollOp::AllReduce, flags)?,
        "broadcast" => manticore_collective(cfg, CollOp::Broadcast, flags)?,
        w @ ("conv-base" | "conv-stacked" | "conv-pipe") => {
            let variant = match w {
                "conv-base" => ConvVariant::Baseline,
                "conv-stacked" => ConvVariant::Stacked,
                _ => ConvVariant::Pipelined,
            };
            let n = cfg.n_clusters();
            let mut ch = Chiplet::new(cfg);
            let stack = if variant == ConvVariant::Baseline { 1 } else { 8 };
            let scripts = conv_scripts(CONV_SMALL, variant, n, stack);
            let res = run_scripts(&mut ch, scripts, 10_000_000);
            println!("{w} on {n} clusters: finished={} cycles={}", res.finished, res.cycles);
            println!(
                "  HBM {:.2} GB/s, cluster ports {:.2} GB/s, level bytes {:?}",
                res.gbps(res.hbm_bytes),
                res.gbps(res.cluster_dma_bytes),
                res.level_bytes
            );
            if ch.telemetry_enabled() {
                println!("  energy: {:.1} pJ for the workload", res.energy_pj);
            }
            drain_chiplet_telemetry(&mut ch, flags)?;
        }
        "fc" => {
            let n = cfg.n_clusters();
            let mut ch = Chiplet::new(cfg);
            let scripts = fc_scripts(8, 16, 32, 32, n);
            let res = run_scripts(&mut ch, scripts, 10_000_000);
            println!("fc on {n} clusters: finished={} cycles={}", res.finished, res.cycles);
            println!("  HBM {:.2} GB/s", res.gbps(res.hbm_bytes));
            if ch.telemetry_enabled() {
                println!("  energy: {:.1} pJ for the workload", res.energy_pj);
            }
            drain_chiplet_telemetry(&mut ch, flags)?;
        }
        w => bail!("unknown workload: {w}"),
    }
    Ok(())
}

/// Multi-chiplet pod all-reduce: N dies over D2D links, hierarchical
/// (default) or flat-ring (`--flat`) schedule, verified element-wise.
fn cmd_multichip(flags: &HashMap<String, String>) -> Result<()> {
    use noc::manticore::pod::{pod_determinism_fingerprint, run_pod_collective, Pod, PodCfg};
    use noc::noc::d2d::D2DCfg;
    let chiplets: usize = flags.get("chiplets").map(|s| s.parse()).transpose()?.unwrap_or(4);
    ensure!((1..=16).contains(&chiplets), "--chiplets must be in 1..=16");
    let die = chiplet_from_flags(flags, true)?;
    let bytes: u64 = flags.get("bytes").map(|s| s.parse()).transpose()?.unwrap_or(16 * 1024);
    let mut d2d = D2DCfg::default();
    if let Some(v) = flags.get("d2d-latency") {
        d2d.latency = v.parse().context("--d2d-latency must be a positive integer")?;
    }
    if let Some(v) = flags.get("d2d-credits") {
        d2d.credits = v.parse().context("--d2d-credits must be a positive integer")?;
    }
    if let Some(v) = flags.get("d2d-serialize") {
        d2d.serialize = v.parse().context("--d2d-serialize must be a positive integer")?;
    }
    let hier = !flags.contains_key("flat");
    let ranks = chiplets * die.n_clusters();
    // Seeded fault injection (--fault-seed/--fault-rate/--fault-kind/...)
    // plus the no-progress watchdog. The watchdog arms automatically
    // whenever a fault plan is present (a dead link must abort with a
    // diagnosis, not burn the 50M-cycle budget); --watchdog N overrides,
    // 0 disables.
    let fault = noc::fault::FaultPlan::from_flags(flags)?;
    let watchdog: u64 = match flags.get("watchdog") {
        Some(v) => v.parse().context("--watchdog must be a cycle count (0 = off)")?,
        None => {
            if fault.is_some() {
                200_000
            } else {
                0
            }
        }
    };
    let mut pod = Pod::new(PodCfg { n_chiplets: chiplets, die, d2d, fault, watchdog });
    let res = run_pod_collective(&mut pod, bytes, 50_000_000, hier)?;
    ensure!(res.finished, "pod all-reduce did not finish within the cycle budget");
    ensure!(res.correct, "pod all-reduce result failed verification");
    if flags.contains_key("fingerprint") {
        println!("{}", pod_determinism_fingerprint(&pod));
        return Ok(());
    }
    let sched = if hier { "hierarchical" } else { "flat ring" };
    println!(
        "{sched} all-reduce over {chiplets} chiplets ({ranks} ranks), {bytes} B payload: \
         {} cycles",
        res.cycles
    );
    println!(
        "  {:.2} B/cycle, {} B over D2D links, result verified on every rank",
        res.bytes_per_cycle, res.d2d_bytes
    );
    if pod.cfg.fault.is_some() {
        let (mut retr, mut drops) = (0u64, 0u64);
        for die in &pod.dies {
            for (_, c) in &die.d2d {
                retr += c.retransmits();
                drops += c.dropped();
            }
        }
        println!(
            "  fault layer: {retr} beats replayed after CRC mismatch, {drops} after drops \
             (payloads verified exact)"
        );
    }
    println!(
        "  engine: {} worker threads, {} shards (one per die)",
        pod.threads(),
        chiplets
    );
    if pod.telemetry_enabled() {
        let e = pod.energy_report();
        println!(
            "  energy: {:.1} pJ total ({:.4} pJ per payload byte)",
            e.total_pj(),
            e.total_pj() / bytes.max(1) as f64
        );
        emit_telemetry(flags, pod.take_trace_events(), e, Some(pod.link_report()))?;
    }
    Ok(())
}

fn cmd_e2e(flags: &HashMap<String, String>) -> Result<()> {
    let dir = flags.get("artifacts").map(|s| s.as_str()).unwrap_or("artifacts");
    let mut rt = noc::runtime::Runtime::new(dir)?;
    println!("PJRT platform: {}", rt.platform());
    for name in ["conv_small", "fc_small", "matmul_128"] {
        rt.load(name)?;
        let r = rt.run_golden(name)?;
        println!(
            "  {name}: max_rel_err {:.2e} {}",
            r.max_rel_err,
            if r.max_rel_err < 1e-4 { "OK" } else { "MISMATCH" }
        );
        ensure!(r.max_rel_err < 1e-4, "{name} numerics mismatch");
    }
    println!("compute artifacts verified; run examples/nn_layer_e2e for the co-simulation");
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: noc <command> [flags]\n\
         commands:\n\
         \x20 figures [--fig N]            regenerate Figs 13-21 series\n\
         \x20 tables  [--tab 1|2|3|4]      regenerate Tables 1-4\n\
         \x20 simulate --config F [--json] [--fingerprint] [--full-scan]\n\
         \x20          [--cycles N] [--threads N] [--epoch E]\n\
         \x20          [--epoch-policy fixed|adaptive]\n\
         \x20          [--telemetry] [--trace FILE]\n\
         \x20                              run a configured topology: flat\n\
         \x20                              [[master]]/[[slave]] or recursive\n\
         \x20                              [topology] template grammar (see\n\
         \x20                              examples/topologies/)\n\
         \x20                              (--threads >= 1: sharded engine,\n\
         \x20                              bit-identical for every N; unset:\n\
         \x20                              host core count; 0: single arena)\n\
         \x20 manticore [--size small|medium|full]\n\
         \x20           [--workload xsection|latency|allreduce|broadcast|\n\
         \x20                       conv-base|conv-stacked|conv-pipe|fc]\n\
         \x20           [--collective ring|tree] [--bytes N]\n\
         \x20           [--cycles N] [--threads N] [--epoch E]\n\
         \x20           [--epoch-policy fixed|adaptive]\n\
         \x20           [--telemetry] [--trace FILE]\n\
         \x20                              case-study simulations (unset\n\
         \x20                              --threads: host core count for\n\
         \x20                              xsection/allreduce/broadcast,\n\
         \x20                              0 for latency/conv/fc)\n\
         \x20 multichip [--chiplets N] [--size small|medium|full]\n\
         \x20           [--bytes N] [--flat] [--fingerprint]\n\
         \x20           [--d2d-latency C] [--d2d-credits N]\n\
         \x20           [--d2d-serialize C] [--threads N] [--epoch E]\n\
         \x20           [--epoch-policy fixed|adaptive] [--pin-workers]\n\
         \x20           [--telemetry] [--trace FILE]\n\
         \x20           [--fault-seed S] [--fault-rate R]\n\
         \x20           [--fault-kind corrupt|drop|dead-link|slverr]\n\
         \x20           [--fault-link NAME] [--fault-at CYCLE]\n\
         \x20           [--fault-addr A] [--fault-len L] [--fault-until C]\n\
         \x20           [--watchdog CYCLES]\n\
         \x20                              N-chiplet pod all-reduce over D2D\n\
         \x20                              links (hierarchical; --flat for\n\
         \x20                              the flat-ring oracle; bit-identical\n\
         \x20                              for every --threads N >= 1).\n\
         \x20                              --fault-* arms seeded injection\n\
         \x20                              (CRC+replay recovers corrupt/drop;\n\
         \x20                              dead-link wedges and the watchdog\n\
         \x20                              aborts with a diagnostic dump;\n\
         \x20                              --watchdog defaults to 200000 when\n\
         \x20                              faults are armed, 0 = off)\n\
         \x20 e2e [--artifacts DIR]        verify PJRT compute artifacts\n\
         telemetry (all simulation commands): --telemetry attaches the\n\
         \x20 activity meters and prints energy + link-utilization reports;\n\
         \x20 --trace FILE also drains the per-shard event rings to Chrome\n\
         \x20 trace_event JSON (open in Perfetto). Both are off by default\n\
         \x20 and bit-identical across --threads / engine modes when on."
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let (_pos, flags) = parse_flags(&args[1..]);
    match cmd.as_str() {
        "figures" => cmd_figures(&flags),
        "tables" => cmd_tables(&flags),
        "simulate" => cmd_simulate(&flags),
        "manticore" => cmd_manticore(&flags),
        "multichip" => cmd_multichip(&flags),
        "e2e" => cmd_e2e(&flags),
        _ => usage(),
    }
}
