//! Minimal error-handling substrate (crates.io is unreachable offline, so
//! `anyhow` is reimplemented at the scale this project needs — the same
//! pattern as `coordinator::config` for serde/toml and `sim::prop` for
//! proptest).
//!
//! Provides the subset of the anyhow surface the codebase uses: a string-y
//! [`Error`] type, [`Result`], a [`Context`] extension trait for `Result`
//! and `Option`, and the `anyhow!`/`bail!`/`ensure!` macros (exported at
//! the crate root, so `noc::bail!` / `crate::bail!`).

use std::fmt;

/// A boxed-string error with accumulated context. Deliberately does NOT
/// implement `std::error::Error`: that keeps the blanket
/// `From<E: std::error::Error>` impl coherent (the same trick anyhow uses).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and convert `Option` to `Result`).
pub trait Context<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<D: fmt::Display>(self, f: impl FnOnce() -> D) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::errors::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::errors::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::errors::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(e.to_string(), "flag was false");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.context("reading file").unwrap_err();
        assert!(e.to_string().starts_with("reading file:"), "{e}");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let s: Option<u32> = Some(1);
        assert_eq!(s.with_context(|| "unused").unwrap(), 1);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn anyhow_macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        assert_eq!(anyhow!("x = {}", 3).to_string(), "x = 3");
        let inner = anyhow!("inner");
        assert_eq!(anyhow!(inner).to_string(), "inner");
    }
}
