//! Deterministic, zero-overhead-when-disabled observability layer.
//!
//! Three pillars, all stamped with *simulated* cycles so every output is
//! bit-identical across `--threads N` and across the event/full-scan
//! engine modes:
//!
//! - [`trace`]: per-shard ring-buffered event traces (component busy
//!   spans, DMA chain legs, collective steps, epoch boundaries, D2D
//!   beats), exported as Chrome `trace_event` JSON for Perfetto.
//! - [`energy`]: per-component active/total cycle integrals multiplied
//!   by §3 area-model-derived dynamic/static power, plus per-byte link
//!   energy from beat counters, rolled up per subsystem.
//! - [`link`]: per-bundle busy-cycle/byte utilization reports built on
//!   the always-on channel statistics taps.
//!
//! Determinism contract: everything reported here derives from
//! `Activity::Active` tick counts, channel handshake counters, and
//! simulated-cycle stamps — none of which depend on the engine mode
//! (sleeping components tick as state-preserving no-ops by the `Idle`
//! contract) or on the worker thread count (shard structure is fixed;
//! threads only change which worker advances a shard). The only caveat
//! is ring-buffer overflow: a trace that dropped events reports the
//! drop count, and ordering of the *surviving* events is restored by
//! sorting on mode-invariant keys at export time.

pub mod energy;
pub mod link;
pub mod trace;

pub use energy::{EnergyReport, D2D_PJ_PER_BYTE, ON_DIE_PJ_PER_BYTE};
pub use link::{link_report_json, LinkTap, LinkUse};
pub use trace::{chrome_trace_json, sort_events, TraceEvent, Tracer, TRACE_CAP};
