//! Link-utilization reports built on the always-on channel statistics.
//!
//! A [`LinkTap`] is a passive pair of [`Tap`]s on a bundle's two data
//! channels (W toward the slave, R back), captured at build time before
//! the endpoints move into their owning modules. Because one channel
//! handshake occupies exactly one cycle (the `protocol::channel`
//! contract), beat counts *are* busy-cycle counts, and
//! `bytes / (cycles × beat_bytes)` is the true utilization of each
//! direction. The report flags saturated trunks (≥ [`SATURATED_FRAC`]
//! of peak) and idle links (zero data beats) — the heatmap a topology
//! DSE reads to find the bottleneck bundle.
//!
//! Everything here derives from handshake counters, which are engine-
//! mode- and thread-count-invariant, so the report is bit-identical
//! across `--threads N` × event/full-scan.

use crate::coordinator::report::Json;
use crate::protocol::channel::Tap;
use crate::protocol::payload::{RBeat, WBeat};
use crate::protocol::port::{MasterEnd, SlaveEnd};
use crate::sim::Cycle;

/// A link counting as "saturated" carries at least this fraction of its
/// peak duplex bandwidth.
pub const SATURATED_FRAC: f64 = 0.8;

/// Passive observer of one bundle's data channels.
pub struct LinkTap {
    label: String,
    w: Tap<WBeat>,
    r: Tap<RBeat>,
    beat_bytes: u64,
}

impl LinkTap {
    pub fn new(label: impl Into<String>, w: Tap<WBeat>, r: Tap<RBeat>, beat_bytes: u64) -> Self {
        LinkTap { label: label.into(), w, r, beat_bytes }
    }

    /// Tap a bundle at its master end (before the end moves into a
    /// module).
    pub fn from_master(label: impl Into<String>, m: &MasterEnd) -> Self {
        LinkTap::new(label, m.w.tap(), m.r.tap(), m.cfg.beat_bytes() as u64)
    }

    /// Tap a bundle at its slave end.
    pub fn from_slave(label: impl Into<String>, s: &SlaveEnd) -> Self {
        LinkTap::new(label, s.w.tap(), s.r.tap(), s.cfg.beat_bytes() as u64)
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Data beats moved (W + R handshakes).
    pub fn data_beats(&self) -> u64 {
        self.w.stats().handshakes + self.r.stats().handshakes
    }

    pub fn bytes(&self) -> u64 {
        self.data_beats() * self.beat_bytes
    }

    /// Producer-side stall cycles on the two data channels.
    pub fn stall_cycles(&self) -> u64 {
        self.w.stats().stall_cycles + self.r.stats().stall_cycles
    }

    /// Snapshot into a [`LinkUse`] over a run of `cycles`.
    pub fn usage(&self, cycles: Cycle) -> LinkUse {
        let beats = self.data_beats();
        LinkUse {
            label: self.label.clone(),
            beats,
            bytes: beats * self.beat_bytes,
            // W and R are independent channels: a fully duplex link
            // reaches 2.0.
            busy_frac: if cycles == 0 { 0.0 } else { beats as f64 / cycles as f64 },
            stall_cycles: self.stall_cycles(),
            retransmits: 0,
        }
    }
}

/// One row of the utilization heatmap.
#[derive(Debug, Clone)]
pub struct LinkUse {
    pub label: String,
    pub beats: u64,
    pub bytes: u64,
    /// Data beats per cycle; duplex peak is 2.0.
    pub busy_frac: f64,
    pub stall_cycles: u64,
    /// Replayed beats on links with a CRC+replay layer (D2D); on-die
    /// bundles are lossless and always report 0.
    pub retransmits: u64,
}

impl LinkUse {
    pub fn saturated(&self) -> bool {
        self.busy_frac >= SATURATED_FRAC
    }

    pub fn idle(&self) -> bool {
        self.beats == 0
    }
}

/// Render the heatmap: all rows plus the saturated/idle call-outs.
pub fn link_report_json(links: &[LinkUse], cycles: Cycle) -> Json {
    let rows = Json::Arr(
        links
            .iter()
            .map(|l| {
                Json::Obj(vec![
                    ("label".into(), Json::Str(l.label.clone())),
                    ("beats".into(), Json::Num(l.beats as f64)),
                    ("bytes".into(), Json::Num(l.bytes as f64)),
                    ("busy_frac".into(), Json::Num(l.busy_frac)),
                    ("stall_cycles".into(), Json::Num(l.stall_cycles as f64)),
                    ("retransmits".into(), Json::Num(l.retransmits as f64)),
                ])
            })
            .collect(),
    );
    let saturated = Json::Arr(
        links.iter().filter(|l| l.saturated()).map(|l| Json::Str(l.label.clone())).collect(),
    );
    let idle =
        Json::Arr(links.iter().filter(|l| l.idle()).map(|l| Json::Str(l.label.clone())).collect());
    Json::Obj(vec![
        ("cycles".into(), Json::Num(cycles as f64)),
        ("links".into(), rows),
        ("saturated".into(), saturated),
        ("idle".into(), idle),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::payload::{Bytes, WBeat};
    use crate::protocol::port::{bundle, BundleCfg};

    #[test]
    fn tap_counts_data_beats_and_bytes() {
        let (m, s) = bundle("t", BundleCfg::default());
        let tap = LinkTap::from_master("t", &m);
        for cy in 0..4u64 {
            m.set_now(cy);
            s.set_now(cy);
            if m.w.can_push() {
                m.w.push(WBeat::full(Bytes::zeroed(8), true, 0));
            }
            if s.w.can_pop() {
                s.w.pop();
            }
        }
        assert_eq!(tap.data_beats(), 3, "3 pops in 4 cycles (1-cycle visibility)");
        assert_eq!(tap.bytes(), 3 * 8);
        let u = tap.usage(4);
        assert!((u.busy_frac - 0.75).abs() < 1e-12);
        assert!(!u.idle() && !u.saturated());
    }

    #[test]
    fn report_flags_saturated_and_idle() {
        let links = vec![
            LinkUse {
                label: "hot".into(),
                beats: 90,
                bytes: 720,
                busy_frac: 0.9,
                stall_cycles: 4,
                retransmits: 0,
            },
            LinkUse {
                label: "cold".into(),
                beats: 0,
                bytes: 0,
                busy_frac: 0.0,
                stall_cycles: 0,
                retransmits: 0,
            },
        ];
        let j = link_report_json(&links, 100).render();
        assert!(j.contains("\"saturated\":[\"hot\"]"), "{j}");
        assert!(j.contains("\"idle\":[\"cold\"]"), "{j}");
    }
}
