//! Energy accounting on top of the calibrated §3 area/timing model.
//!
//! Every component's `Activity::Active` tick count (collected by the
//! engine meter) is multiplied by an area-model-derived dynamic energy
//! per active cycle, plus a static leakage term over *all* simulated
//! cycles; link energy is beat-counter bytes times a per-byte cost
//! (on-die wires vs off-die D2D SerDes). Components are classified into
//! subsystems by name at report time — nothing here runs on the hot
//! path.
//!
//! Units: everything is stored as integer **femtojoules** (`u64`).
//! Quantizing each term once at insertion makes every rollup an integer
//! sum, so per-component, per-subsystem, and whole-system totals are
//! *exactly* conserved regardless of summation order — the conservation
//! test asserts equality, not approximate closeness — and the report is
//! bit-identical across thread counts and engine modes.
//!
//! Energy per active cycle is frequency-independent under the §3.8 power
//! law: `power = kGE · f · MW_PER_KGE_GHZ` integrated over one cycle of
//! length `1/f` ns gives `kGE · MW_PER_KGE_GHZ` pJ.

use crate::area::calib::MW_PER_KGE_GHZ;
use crate::area::model::{area_timing, Module};
use crate::coordinator::report::Json;
use crate::sim::Cycle;

/// Static (leakage + clock-tree) power as a fraction of full-load
/// dynamic power, applied over every simulated cycle. GF22FDX at
/// 0.8 V/25 °C leaks little; 10% is the usual planning number.
pub const STATIC_FRAC: f64 = 0.10;

/// On-die link wire energy (pJ/byte): ~0.1 pJ/byte for millimeter-scale
/// 22FDX interconnect at 0.8 V.
pub const ON_DIE_PJ_PER_BYTE: f64 = 0.10;

/// Off-die die-to-die energy (pJ/byte): ~1 pJ/byte, the usual figure
/// for short-reach organic-substrate D2D PHYs (an order of magnitude
/// above on-die wires).
pub const D2D_PJ_PER_BYTE: f64 = 1.00;

/// Fallback area for components the classifier does not recognize
/// (generators, monitors, glue).
pub const DEFAULT_KGE: f64 = 5.0;

/// A compute cluster (cores + FPUs + L1 banks behind it) dwarfs any NoC
/// module; order-of-magnitude planning figure for an 8-core cluster.
pub const CORE_KGE: f64 = 600.0;

/// D2D PHY + protocol controller logic per direction.
pub const D2D_KGE: f64 = 40.0;

fn pj_per_active_cycle(kge: f64) -> f64 {
    kge * MW_PER_KGE_GHZ
}

fn to_fj(pj: f64) -> u64 {
    (pj * 1000.0).round() as u64
}

/// Classify a component by its hierarchical name into a subsystem label
/// and a representative kGE area from the §3 model.
///
/// Substring order matters — names overlap. `.dmamux`, `.dmaremap`, and
/// `.dma0.split` must hit the mux/remap/demux arms before the `.dma`
/// arm; the error slave's `.errslv` must win before anything else.
pub fn classify(name: &str) -> (&'static str, f64) {
    if name.contains(".errslv") {
        ("errslv", 1.0)
    } else if name.contains(".iq") || name.contains(".pipe") || name.contains("cut.") {
        // Input queues, pipeline stages, shard-cut relays: a register
        // slice per channel.
        ("pipeline", 2.0)
    } else if name.contains(".split") || name.contains(".demux") {
        ("noc", area_timing(Module::Demux { m: 4, i: 6 }).kge)
    } else if name.contains("mux") {
        // .mux / .dmamux / .l1muxA / .l1muxB
        ("noc", area_timing(Module::Mux { s: 4, i: 6 }).kge)
    } else if name.contains("remap") {
        ("noc", area_timing(Module::IdRemap { i: 6, u: 16, t: 8 }).kge)
    } else if name.contains(".upsizer") {
        ("noc", area_timing(Module::Upsizer { dn: 64, dw: 512, r: 1 }).kge)
    } else if name.contains("hbm") || name.contains(".l1a") || name.contains(".l1b") || name.contains("io") {
        ("mem", area_timing(Module::MemDuplex { d: 512, b: 2 }).kge)
    } else if name.contains(".dma") {
        ("dma", area_timing(Module::Dma { d: 512 }).kge)
    } else if name.contains(".cores") {
        ("cores", CORE_KGE)
    } else if name.contains(".coll") {
        ("collective", 20.0)
    } else if name.contains("d2d") {
        ("d2d", D2D_KGE)
    } else {
        ("other", DEFAULT_KGE)
    }
}

/// One component's energy line.
#[derive(Debug, Clone)]
pub struct CompEnergy {
    pub name: String,
    pub subsystem: &'static str,
    /// Cycles this component returned `Activity::Active`.
    pub active: u64,
    pub kge: f64,
    pub dyn_fj: u64,
    pub static_fj: u64,
}

/// One link's beat-count energy line.
#[derive(Debug, Clone)]
pub struct LinkEnergy {
    pub label: String,
    pub bytes: u64,
    pub fj: u64,
}

/// Whole-system energy report; build with [`EnergyReport::new`], feed
/// component active counts and link byte counts, then render.
#[derive(Debug, Clone)]
pub struct EnergyReport {
    /// Simulated cycles covered (static energy integrates over these).
    pub cycles: Cycle,
    pub comps: Vec<CompEnergy>,
    pub links: Vec<LinkEnergy>,
}

impl EnergyReport {
    pub fn new(cycles: Cycle) -> Self {
        EnergyReport { cycles, comps: Vec::new(), links: Vec::new() }
    }

    /// Add a component by name and active-cycle count; classification
    /// and quantization happen here, once.
    pub fn add_component(&mut self, name: &str, active: u64) {
        let (subsystem, kge) = classify(name);
        let per_cycle_pj = pj_per_active_cycle(kge);
        self.comps.push(CompEnergy {
            name: name.to_string(),
            subsystem,
            active,
            kge,
            dyn_fj: to_fj(active as f64 * per_cycle_pj),
            static_fj: to_fj(self.cycles as f64 * STATIC_FRAC * per_cycle_pj),
        });
    }

    /// Add a link's byte count at a per-byte energy cost.
    pub fn add_link(&mut self, label: &str, bytes: u64, pj_per_byte: f64) {
        self.links.push(LinkEnergy {
            label: label.to_string(),
            bytes,
            fj: to_fj(bytes as f64 * pj_per_byte),
        });
    }

    /// Fold another report into this one (pod rollup over dies).
    pub fn merge(&mut self, other: EnergyReport) {
        self.cycles = self.cycles.max(other.cycles);
        self.comps.extend(other.comps);
        self.links.extend(other.links);
    }

    pub fn dynamic_fj(&self) -> u64 {
        self.comps.iter().map(|c| c.dyn_fj).sum()
    }

    pub fn static_fj(&self) -> u64 {
        self.comps.iter().map(|c| c.static_fj).sum()
    }

    pub fn link_fj(&self) -> u64 {
        self.links.iter().map(|l| l.fj).sum()
    }

    /// Exact whole-system total (integer sum of every line item).
    pub fn total_fj(&self) -> u64 {
        self.dynamic_fj() + self.static_fj() + self.link_fj()
    }

    pub fn total_pj(&self) -> f64 {
        self.total_fj() as f64 / 1000.0
    }

    /// Per-subsystem rollup (component dyn+static; links under "links"),
    /// in first-appearance order — deterministic because components are
    /// added in slot order.
    pub fn by_subsystem(&self) -> Vec<(&'static str, u64)> {
        let mut out: Vec<(&'static str, u64)> = Vec::new();
        let mut add = |key: &'static str, fj: u64| match out.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => *v += fj,
            None => out.push((key, fj)),
        };
        for c in &self.comps {
            add(c.subsystem, c.dyn_fj + c.static_fj);
        }
        for l in &self.links {
            add("links", l.fj);
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let by_sub = Json::Obj(
            self.by_subsystem()
                .into_iter()
                .map(|(k, fj)| (k.to_string(), Json::Num(fj as f64 / 1000.0)))
                .collect(),
        );
        let comps = Json::Arr(
            self.comps
                .iter()
                .map(|c| {
                    Json::Obj(vec![
                        ("name".into(), Json::Str(c.name.clone())),
                        ("subsystem".into(), Json::Str(c.subsystem.into())),
                        ("active_cycles".into(), Json::Num(c.active as f64)),
                        ("kge".into(), Json::Num(c.kge)),
                        ("dyn_fj".into(), Json::Num(c.dyn_fj as f64)),
                        ("static_fj".into(), Json::Num(c.static_fj as f64)),
                    ])
                })
                .collect(),
        );
        let links = Json::Arr(
            self.links
                .iter()
                .map(|l| {
                    Json::Obj(vec![
                        ("label".into(), Json::Str(l.label.clone())),
                        ("bytes".into(), Json::Num(l.bytes as f64)),
                        ("fj".into(), Json::Num(l.fj as f64)),
                    ])
                })
                .collect(),
        );
        Json::Obj(vec![
            ("cycles".into(), Json::Num(self.cycles as f64)),
            ("total_pj".into(), Json::Num(self.total_pj())),
            ("dynamic_fj".into(), Json::Num(self.dynamic_fj() as f64)),
            ("static_fj".into(), Json::Num(self.static_fj() as f64)),
            ("link_fj".into(), Json::Num(self.link_fj() as f64)),
            ("by_subsystem_pj".into(), by_sub),
            ("components".into(), comps),
            ("links".into(), links),
        ])
    }

    pub fn render(&self) -> String {
        self.to_json().render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_disambiguates_overlapping_names() {
        assert_eq!(classify("die0.xp.errslv0").0, "errslv");
        assert_eq!(classify("die0.xp.iq2").0, "pipeline");
        assert_eq!(classify("cut.c3.up").0, "pipeline");
        assert_eq!(classify("c0.dma0.split").0, "noc");
        assert_eq!(classify("c0.dmamux").0, "noc");
        assert_eq!(classify("c0.dmaremap").0, "noc");
        assert_eq!(classify("c0.dma0").0, "dma");
        assert_eq!(classify("c1.cores").0, "cores");
        assert_eq!(classify("c1.coll").0, "collective");
        assert_eq!(classify("pod.d2d0to1").0, "d2d");
        assert_eq!(classify("hbm0").0, "mem");
        assert_eq!(classify("c0.l1a").0, "mem");
    }

    #[test]
    fn energy_is_exactly_conserved() {
        let mut r = EnergyReport::new(10_000);
        for (name, active) in
            [("c0.dma0", 1234u64), ("c0.cores", 9_999), ("xp.mux0", 57), ("xp.errslv0", 0)]
        {
            r.add_component(name, active);
        }
        r.add_link("trunk0", 4096, ON_DIE_PJ_PER_BYTE);
        r.add_link("d2d0to1", 512, D2D_PJ_PER_BYTE);
        // Integer-fJ storage: per-line items sum exactly to the total.
        let line_sum: u64 = r.comps.iter().map(|c| c.dyn_fj + c.static_fj).sum::<u64>()
            + r.links.iter().map(|l| l.fj).sum::<u64>();
        assert_eq!(line_sum, r.total_fj());
        let sub_sum: u64 = r.by_subsystem().iter().map(|(_, fj)| fj).sum();
        assert_eq!(sub_sum, r.total_fj());
    }

    #[test]
    fn dynamic_energy_scales_with_activity() {
        let mut r = EnergyReport::new(1000);
        r.add_component("a.dma0", 100);
        r.add_component("b.dma0", 200);
        assert_eq!(r.comps[0].static_fj, r.comps[1].static_fj);
        // Quantized per-line, so allow 1 fJ of rounding.
        assert!((2 * r.comps[0].dyn_fj).abs_diff(r.comps[1].dyn_fj) <= 1);
    }

    #[test]
    fn link_energy_orders_of_magnitude() {
        let mut r = EnergyReport::new(1);
        r.add_link("on", 1000, ON_DIE_PJ_PER_BYTE);
        r.add_link("off", 1000, D2D_PJ_PER_BYTE);
        assert_eq!(r.links[0].fj, 100_000); // 1000 B × 0.1 pJ/B
        assert_eq!(r.links[1].fj, 1_000_000);
    }

    #[test]
    fn merge_rolls_up_dies() {
        let mut a = EnergyReport::new(500);
        a.add_component("d0.dma0", 10);
        let mut b = EnergyReport::new(500);
        b.add_component("d1.dma0", 10);
        let t0 = a.total_fj();
        let t1 = b.total_fj();
        a.merge(b);
        assert_eq!(a.total_fj(), t0 + t1);
        assert_eq!(a.cycles, 500);
    }

    #[test]
    fn json_has_headline_fields() {
        let mut r = EnergyReport::new(100);
        r.add_component("c0.cores", 50);
        let s = r.render();
        assert!(s.contains("\"total_pj\":"), "{s}");
        assert!(s.contains("\"by_subsystem_pj\":{\"cores\":"), "{s}");
    }
}
