//! Event tracing: per-shard ring buffers of simulated-cycle-stamped
//! events, exported as Chrome `trace_event` JSON (viewable in Perfetto).
//!
//! A [`Tracer`] is a cheap `Rc` handle onto one shard's ring buffer.
//! Components inside a shard clone it (the same single-shard-confinement
//! rule every channel `Rc` already obeys); the engine's meter emits
//! component busy spans into it, and instrumented components (DMA,
//! collective unit, D2D link) emit their own domain events.
//!
//! Events carry only mode-invariant data: the simulated cycle stamp, the
//! owning shard (`pid` in the Chrome format), a deterministic `tid`
//! assigned at construction time, a name, and one integer argument.
//! Within a cycle the *insertion* order may differ between engine modes
//! (tick order of simultaneously-awake components is an engine detail),
//! so [`sort_events`] restores a canonical order on mode-invariant keys
//! before export; the export is therefore bit-identical across
//! `--threads N` and engine modes as long as no ring overflowed (the
//! drop count is part of the export, so an overflow is visible).

use std::cell::RefCell;
use std::rc::Rc;

use crate::coordinator::report::Json;
use crate::sim::Cycle;

/// Events retained per shard ring. Overflow drops *new* events (counted);
/// sized so every test/smoke trace fits with a wide margin while a
/// runaway multi-million-cycle trace stays bounded in memory.
pub const TRACE_CAP: usize = 1 << 16;

/// One trace event: a span (`dur > 0`) or an instant (`dur == 0`), both
/// rendered as Chrome `"ph":"X"` complete events.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    /// Simulated cycle of the event start.
    pub ts: Cycle,
    /// Span length in cycles (0 = instant).
    pub dur: Cycle,
    /// Owning shard (Chrome `pid`).
    pub shard: u32,
    /// Deterministic lane within the shard (Chrome `tid`).
    pub tid: u32,
    pub name: String,
    /// One integer argument (handle, byte count, group count, ...).
    pub arg: u64,
}

struct TraceBuf {
    events: Vec<TraceEvent>,
    dropped: u64,
}

/// Cloneable handle onto one shard's trace ring.
#[derive(Clone)]
pub struct Tracer {
    buf: Rc<RefCell<TraceBuf>>,
    shard: u32,
    tid: u32,
}

impl Tracer {
    pub fn new(shard: u32) -> Self {
        Tracer {
            buf: Rc::new(RefCell::new(TraceBuf { events: Vec::new(), dropped: 0 })),
            shard,
            tid: 0,
        }
    }

    /// A handle onto the same ring stamping a fixed `tid` (one lane per
    /// instrumented component, assigned in construction order).
    pub fn with_tid(&self, tid: u32) -> Self {
        Tracer { buf: self.buf.clone(), shard: self.shard, tid }
    }

    pub fn shard(&self) -> u32 {
        self.shard
    }

    /// Record a span of `dur` cycles starting at `ts`.
    pub fn span(&self, ts: Cycle, dur: Cycle, name: &str, arg: u64) {
        self.push(TraceEvent { ts, dur, shard: self.shard, tid: self.tid, name: name.into(), arg });
    }

    /// Record an instant event.
    pub fn instant(&self, ts: Cycle, name: &str, arg: u64) {
        self.span(ts, 0, name, arg);
    }

    /// Span with an explicit lane (used by the engine meter, which lanes
    /// spans by component slot index).
    pub fn span_on(&self, tid: u32, ts: Cycle, dur: Cycle, name: &str, arg: u64) {
        self.push(TraceEvent { ts, dur, shard: self.shard, tid, name: name.into(), arg });
    }

    fn push(&self, ev: TraceEvent) {
        let mut b = self.buf.borrow_mut();
        if b.events.len() < TRACE_CAP {
            b.events.push(ev);
        } else {
            b.dropped += 1;
        }
    }

    /// Account events a producer discarded before they reached the ring
    /// (e.g. the engine meter's bounded span list).
    pub fn note_dropped(&self, n: u64) {
        self.buf.borrow_mut().dropped += n;
    }

    /// Take all buffered events (and the drop count), leaving the ring
    /// empty. Main-thread-only, between runs.
    pub fn drain(&self) -> (Vec<TraceEvent>, u64) {
        let mut b = self.buf.borrow_mut();
        let dropped = b.dropped;
        b.dropped = 0;
        (std::mem::take(&mut b.events), dropped)
    }

    /// Buffered event count (tests / overflow checks).
    pub fn len(&self) -> usize {
        self.buf.borrow().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Canonical event order: every key is mode- and thread-count-invariant,
/// so the sorted stream is deterministic even though insertion order
/// within a cycle is not.
pub fn sort_events(events: &mut [TraceEvent]) {
    events.sort_by(|a, b| {
        (a.ts, a.shard, a.tid, &a.name, a.dur, a.arg)
            .cmp(&(b.ts, b.shard, b.tid, &b.name, b.dur, b.arg))
    });
}

/// Render a Chrome `trace_event` JSON document. `ts`/`dur` are emitted
/// in the format's microsecond field, one simulated cycle per
/// microsecond — Perfetto's time axis then reads directly in cycles.
pub fn chrome_trace_json(events: &[TraceEvent], dropped: u64) -> String {
    let evs: Vec<Json> = events
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("name".into(), Json::Str(e.name.clone())),
                ("ph".into(), Json::Str("X".into())),
                ("ts".into(), Json::Num(e.ts as f64)),
                ("dur".into(), Json::Num(e.dur as f64)),
                ("pid".into(), Json::Num(e.shard as f64)),
                ("tid".into(), Json::Num(e.tid as f64)),
                ("args".into(), Json::Obj(vec![("v".into(), Json::Num(e.arg as f64))])),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(evs)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
        ("droppedEvents".into(), Json::Num(dropped as f64)),
    ])
    .render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_and_instants_round_trip() {
        let t = Tracer::new(3);
        t.span(10, 5, "busy", 0);
        t.with_tid(7).instant(12, "beat", 64);
        let (mut evs, dropped) = t.drain();
        assert_eq!(dropped, 0);
        assert_eq!(evs.len(), 2);
        sort_events(&mut evs);
        assert_eq!(evs[0].name, "busy");
        assert_eq!(evs[0].shard, 3);
        assert_eq!(evs[1].tid, 7);
        assert_eq!(evs[1].dur, 0);
        assert!(t.is_empty(), "drain empties the ring");
    }

    #[test]
    fn overflow_drops_and_counts() {
        let t = Tracer::new(0);
        for i in 0..(TRACE_CAP as u64 + 10) {
            t.instant(i, "e", 0);
        }
        let (evs, dropped) = t.drain();
        assert_eq!(evs.len(), TRACE_CAP);
        assert_eq!(dropped, 10);
    }

    #[test]
    fn sort_is_insertion_order_invariant() {
        let mk = |order: &[usize]| {
            let evs = [
                TraceEvent { ts: 5, dur: 1, shard: 0, tid: 2, name: "a".into(), arg: 0 },
                TraceEvent { ts: 5, dur: 0, shard: 0, tid: 1, name: "b".into(), arg: 0 },
                TraceEvent { ts: 4, dur: 9, shard: 1, tid: 0, name: "c".into(), arg: 0 },
            ];
            let mut v: Vec<TraceEvent> = order.iter().map(|&i| evs[i].clone()).collect();
            sort_events(&mut v);
            v
        };
        assert_eq!(mk(&[0, 1, 2]), mk(&[2, 1, 0]));
        assert_eq!(mk(&[0, 1, 2]), mk(&[1, 2, 0]));
    }

    #[test]
    fn chrome_json_shape() {
        let t = Tracer::new(1);
        t.span(2, 3, "x\"y", 7);
        let (evs, dropped) = t.drain();
        let j = chrome_trace_json(&evs, dropped);
        assert!(j.contains("\"traceEvents\":["), "{j}");
        assert!(j.contains("\"ph\":\"X\""), "{j}");
        assert!(j.contains("\"name\":\"x\\\"y\""), "{j}");
        assert!(j.contains("\"droppedEvents\":0"), "{j}");
    }
}
