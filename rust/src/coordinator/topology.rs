//! Recursive topology grammar: composable subnetwork templates.
//!
//! The flat `[sim]` config (`coordinator::config::SimCfg`) describes one
//! crossbar. Real SoCs are trees of them — clusters behind cluster
//! crossbars behind a chip-level interconnect, mixed-width accelerator
//! islands, slow-clock peripheral subsystems. This module grows the
//! config surface into a grammar for exactly that shape:
//!
//! - `[[template]]` declares a reusable subnetwork: local masters,
//!   slaves, a crossbar, and *child* instantiations of other templates.
//! - `[[template.child]]` stamps a named template `count` times, placing
//!   each instance's address window at `base + k * stride` inside the
//!   parent — base-address strides and name prefixes (`cluster3.dsp.`)
//!   are derived, not hand-written.
//! - `[topology]` picks the root template and the engine options.
//!
//! Parent and child crossbars are linked through a typed trunk (one
//! downlink, one uplink) that auto-inserts the §2 converter palette:
//! `Upsizer`/`Downsizer` on a data-width mismatch, a `cdc` pair on a
//! clock mismatch (`clock_ps` differs), and an ID-width converter
//! (`IdRemap` or `IdSerialize`, per the child's `id_policy`) always —
//! the parent crossbar's prepend bits structurally never fit the child's
//! ID space. Setting `converters = false` on a child turns the implicit
//! width/clock stages into hard config errors for designs that must stay
//! homogeneous; the ID boundary stage is kept even then.
//!
//! Address decode is absolute end-to-end: each level's map claims its
//! local slaves and child windows, routes everything outside its own
//! window to the uplink, and DECERRs in-window holes locally — a hole
//! can never ping-pong between a parent and child map.
//!
//! With `threads >= 1` the walk shards the system exactly like the flat
//! builder: shard 0 holds the root crossbar and root slaves, each root
//! master island gets its own shard, and each *top-level* child instance
//! becomes one shard with its whole subtree inside; the trunks of those
//! instances are cut with `protocol::exchange` relays. The shard
//! structure depends only on the config, so
//! [`crate::coordinator::determinism_fingerprint`] is bit-identical for
//! every thread count. A degenerate root template (masters + slaves, no
//! children) reproduces the flat builder name for name and seed for
//! seed, so a `[sim]` config and its grammar rewrite fingerprint
//! identically too (`rust/tests/topology_grammar.rs`).

use std::cell::RefCell;
use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::bail;
use crate::errors::{Context, Result};

use crate::coordinator::builder::{gen_cfg, SlaveTap, System};
use crate::coordinator::config::{
    self, master_from_table, slave_from_table, Doc, MasterCfg, SlaveCfg, SlaveKind,
};
use crate::noc::addr_decode::{AddrMap, AddrRule, DefaultPort};
use crate::noc::mem_duplex::{BankArray, MemDuplex};
use crate::noc::mem_simplex::{ArbPolicy, MemSimplex};
use crate::noc::sram::Sram;
use crate::noc::xbar::{xbar_master_id_bits, Xbar, XbarCfg};
use crate::noc::{cdc, Downsizer, IdRemap, IdSerialize, Upsizer};
use crate::protocol::exchange::cut_slave_export;
use crate::protocol::{bundle, BundleCfg, BundleCut, MasterEnd, Monitor, SlaveEnd};
use crate::sim::{shared, Arena, Component, Cycle, DomainId, EngineOpts, Ps};
use crate::traffic::gen::RwGen;
use crate::traffic::perfect_slave::PerfectSlave;

/// Period of the implicit root clock domain; templates inherit it unless
/// they set `clock_ps`.
pub const ROOT_CLOCK_PS: Ps = 1000;

/// Transactions per (ID, direction) in every crossbar demux and ID
/// converter the grammar instantiates (the flat builder's value).
const TXNS_PER_ID: u32 = 8;

/// Per-channel FIFO depth of auto-inserted CDCs.
const CDC_DEPTH: usize = 8;

/// Guardrail against configs whose `count`s multiply into something the
/// walk (and the host) could never finish instantiating.
const MAX_INSTANCES: u64 = 100_000;

/// How a trunk converts the parent's (wider) ID space down to the
/// child's: a table-based remapper or a serializing funnel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IdPolicy {
    Remap,
    Serialize,
}

/// One `[[template.master]]`: a flat [`MasterCfg`] plus its address
/// scope.
#[derive(Debug, Clone)]
pub struct TopoMaster {
    pub cfg: MasterCfg,
    /// `scope = "global"`: `base` is absolute. Default (`"local"`):
    /// `base` is relative to the enclosing instance's window, so every
    /// stamped copy targets its own copy of the subnetwork.
    pub global: bool,
}

/// One `[[template.child]]`: stamp `template` `count` times.
#[derive(Debug, Clone)]
pub struct ChildCfg {
    pub template: String,
    /// Instance name prefix (default: the template name). With
    /// `count > 1` instances are `name0`, `name1`, ...
    pub name: String,
    pub count: usize,
    /// Offset of instance 0's window inside the parent.
    pub base: u64,
    /// Distance between consecutive instance windows (default: the
    /// child's window size, i.e. densely stacked).
    pub stride: Option<u64>,
    /// `false`: a width or clock mismatch on this edge is a config
    /// error instead of an implicit converter.
    pub converters: bool,
    pub id_policy: IdPolicy,
}

/// One `[[template]]`: a reusable subnetwork.
#[derive(Debug, Clone)]
pub struct TemplateCfg {
    pub name: String,
    pub data_bits: usize,
    pub id_bits: usize,
    /// Clock period of this subnetwork (inherited from the parent when
    /// unset; the root inherits [`ROOT_CLOCK_PS`]).
    pub clock_ps: Option<Ps>,
    pub pipeline: bool,
    /// Explicit window size (default: the contents' footprint).
    pub size: Option<u64>,
    pub masters: Vec<TopoMaster>,
    pub slaves: Vec<SlaveCfg>,
    pub children: Vec<ChildCfg>,
}

/// A parsed `[topology]` document: the grammar's top level.
#[derive(Debug, Clone)]
pub struct TopoCfg {
    pub cycles: u64,
    pub engine: EngineOpts,
    pub root: String,
    pub templates: Vec<TemplateCfg>,
}

impl TopoCfg {
    pub fn from_str_toml(text: &str) -> Result<TopoCfg> {
        Self::from_doc(&config::parse(text)?)
    }

    pub fn from_doc(doc: &Doc) -> Result<TopoCfg> {
        let topo = doc.table("topology").context("missing [topology] section")?;
        let ctx = "topology";
        let cycles = topo.get_or(ctx, "cycles", 10_000)?;
        let engine = EngineOpts::from_table(topo, ctx)?;
        let root: String = topo.require(ctx, "root")?;

        let mut templates = Vec::new();
        for (k, t) in doc.array("template").iter().enumerate() {
            let name: String = t.require(&format!("template[{k}]"), "name")?;
            let ctx = format!("template[{name}]");
            let mut masters = Vec::new();
            for (i, mt) in doc.scoped("template", k, "master").iter().enumerate() {
                let mctx = format!("{ctx}.master[{i}]");
                let cfg = master_from_table(mt, &mctx, i)?;
                let global = match mt.get_or(&mctx, "scope", "local".to_string())?.as_str() {
                    "local" => false,
                    "global" => true,
                    s => bail!("{mctx}.scope: expected \"local\" or \"global\", got \"{s}\""),
                };
                masters.push(TopoMaster { cfg, global });
            }
            let mut slaves = Vec::new();
            for (i, st) in doc.scoped("template", k, "slave").iter().enumerate() {
                slaves.push(slave_from_table(st, &format!("{ctx}.slave[{i}]"), i)?);
            }
            let mut children = Vec::new();
            for (i, ct) in doc.scoped("template", k, "child").iter().enumerate() {
                let cctx = format!("{ctx}.child[{i}]");
                let template: String = ct.require(&cctx, "template")?;
                let count = ct.get_or(&cctx, "count", 1usize)?;
                if count == 0 {
                    bail!("{cctx}.count: must be at least 1");
                }
                let policy: String = ct.get_or(&cctx, "id_policy", "remap".to_string())?;
                let id_policy = match policy.as_str() {
                    "remap" => IdPolicy::Remap,
                    "serialize" => IdPolicy::Serialize,
                    s => {
                        bail!("{cctx}.id_policy: expected \"remap\" or \"serialize\", got \"{s}\"")
                    }
                };
                children.push(ChildCfg {
                    name: ct.get_or(&cctx, "name", template.clone())?,
                    template,
                    count,
                    base: ct.get_or(&cctx, "base", 0)?,
                    stride: ct.get_opt(&cctx, "stride")?,
                    converters: ct.get_or(&cctx, "converters", true)?,
                    id_policy,
                });
            }
            templates.push(TemplateCfg {
                name,
                data_bits: t.get_or(&ctx, "data_bits", 64)?,
                id_bits: t.get_or(&ctx, "id_bits", 4)?,
                clock_ps: t.get_opt(&ctx, "clock_ps")?,
                pipeline: t.get_or(&ctx, "pipeline", false)?,
                size: t.get_opt(&ctx, "size")?,
                masters,
                slaves,
                children,
            });
        }
        Ok(TopoCfg { cycles, engine, root, templates })
    }

    /// Validate the grammar and build the system. Every malformed config
    /// is a typed `Err` naming the offending template — never a panic
    /// from deeper layers (`AddrMap` overlap asserts, converter width
    /// asserts) whose message knows nothing about the grammar.
    pub fn build(&self) -> Result<System> {
        let res = self.resolve()?;
        let root_t = &self.templates[res.root];
        let epoch = self.engine.epoch.max(1);
        let top_instances: usize = root_t.children.iter().map(|c| c.count).sum();
        let n_shards = 1 + root_t.masters.len() + top_instances;
        // `Arena::new` applies threads/epoch/policy/full_scan itself;
        // `epoch` stays local for the cut-relay capacities in the walk.
        let arena = Arena::new(&self.engine, n_shards);
        let mut w = Walk {
            cfg: self,
            res: &res,
            arena,
            epoch,
            domains: HashMap::new(),
            gens: Vec::new(),
            monitors: Vec::new(),
            taps: Vec::new(),
            seed_idx: 0,
            next_top_shard: 1 + root_t.masters.len(),
        };
        let root_clock = root_t.clock_ps.unwrap_or(ROOT_CLOCK_PS);
        w.level(res.root, "", 0, root_clock, Place::Root, None)?;
        Ok(System::from_parts("system".into(), w.arena, w.gens, w.monitors, w.taps))
    }

    /// Static validation: resolve template references, reject cycles and
    /// address overlaps, compute per-template address windows.
    fn resolve(&self) -> Result<Resolved> {
        let n = self.templates.len();
        if n == 0 {
            bail!("topology declares no [[template]]s");
        }
        let mut ix = HashMap::new();
        for (i, t) in self.templates.iter().enumerate() {
            if ix.insert(t.name.as_str(), i).is_some() {
                bail!("duplicate template name: {}", t.name);
            }
            let ctx = format!("template[{}]", t.name);
            if t.data_bits == 0 || t.data_bits % 8 != 0 {
                bail!("{ctx}: data_bits must be a positive multiple of 8, got {}", t.data_bits);
            }
            if !(1..=12).contains(&t.id_bits) {
                bail!("{ctx}: id_bits must be within 1..=12, got {}", t.id_bits);
            }
            if t.clock_ps == Some(0) {
                bail!("{ctx}: clock_ps must be positive");
            }
        }
        let Some(&root) = ix.get(self.root.as_str()) else {
            bail!("topology.root: unknown template \"{}\"", self.root);
        };
        let mut child_ix = Vec::with_capacity(n);
        for t in &self.templates {
            let mut cs = Vec::with_capacity(t.children.len());
            for (c, cc) in t.children.iter().enumerate() {
                match ix.get(cc.template.as_str()) {
                    Some(&j) => cs.push(j),
                    None => bail!(
                        "template[{}].child[{c}]: unknown template \"{}\"",
                        t.name,
                        cc.template
                    ),
                }
            }
            child_ix.push(cs);
        }
        let mut color = vec![0u8; n];
        let mut stack = Vec::new();
        for i in 0..n {
            if color[i] == 0 {
                find_cycle(i, &self.templates, &child_ix, &mut color, &mut stack)?;
            }
        }
        let mut memo = vec![None; n];
        for i in 0..n {
            window_of(i, &self.templates, &child_ix, &mut memo)?;
        }
        let window: Vec<u64> = memo.into_iter().map(|w| w.unwrap()).collect();
        for (i, t) in self.templates.iter().enumerate() {
            check_overlaps(t, &child_ix[i], &window)?;
        }
        let root_clock = self.templates[root].clock_ps.unwrap_or(ROOT_CLOCK_PS);
        self.check_edges(root, root_clock, &child_ix, &mut HashSet::new())?;

        let mut totals = vec![None; n];
        let (gens, slaves, instances) = totals_of(root, &self.templates, &child_ix, &mut totals);
        if gens == 0 {
            bail!("topology instantiates no traffic generators (add [[template.master]]s)");
        }
        if slaves == 0 {
            bail!("topology instantiates no slaves (add [[template.slave]]s)");
        }
        if instances > MAX_INSTANCES {
            bail!("topology instantiates {instances} template instances (limit {MAX_INSTANCES})");
        }
        Ok(Resolved { root, child_ix, window })
    }

    /// Walk every reachable parent→child edge once per (template, clock)
    /// pair: widths must divide, and with `converters = false` any width
    /// or clock mismatch is a config error. Clocks resolve down the
    /// instantiation paths (a child inherits its parent's period), hence
    /// the memo key — a diamond instantiated at two different periods is
    /// checked under both.
    fn check_edges(
        &self,
        t_ix: usize,
        clock: Ps,
        child_ix: &[Vec<usize>],
        seen: &mut HashSet<(usize, Ps)>,
    ) -> Result<()> {
        if !seen.insert((t_ix, clock)) {
            return Ok(());
        }
        let t = &self.templates[t_ix];
        for (c, cc) in t.children.iter().enumerate() {
            let ct = &self.templates[child_ix[t_ix][c]];
            let child_clock = ct.clock_ps.unwrap_or(clock);
            if ct.data_bits != t.data_bits {
                if !cc.converters {
                    bail!(
                        "template[{}].child[{c}] ({}): width mismatch ({} vs {} bits) with \
                         converters disabled",
                        t.name,
                        cc.name,
                        t.data_bits,
                        ct.data_bits
                    );
                }
                let hi = t.data_bits.max(ct.data_bits);
                let lo = t.data_bits.min(ct.data_bits);
                if hi % lo != 0 {
                    bail!(
                        "template[{}].child[{c}] ({}): width {hi} is not a multiple of {lo}, no \
                         converter chain fits",
                        t.name,
                        cc.name
                    );
                }
            }
            if child_clock != clock && !cc.converters {
                bail!(
                    "template[{}].child[{c}] ({}): clock mismatch ({clock} ps vs {child_clock} \
                     ps) with converters disabled",
                    t.name,
                    cc.name
                );
            }
            self.check_edges(child_ix[t_ix][c], child_clock, child_ix, seen)?;
        }
        Ok(())
    }
}

/// Validation output: the root template's index, resolved child
/// references, and each template's address-window size.
struct Resolved {
    root: usize,
    child_ix: Vec<Vec<usize>>,
    window: Vec<u64>,
}

fn n_slave_ports(t: &TemplateCfg, has_parent: bool) -> usize {
    let stamped: usize = t.children.iter().map(|c| c.count).sum();
    t.masters.len() + stamped + usize::from(has_parent)
}

/// DFS cycle detection over the template reference graph (color: 0 =
/// unvisited, 1 = on the current path, 2 = done).
fn find_cycle(
    i: usize,
    templates: &[TemplateCfg],
    child_ix: &[Vec<usize>],
    color: &mut [u8],
    stack: &mut Vec<usize>,
) -> Result<()> {
    color[i] = 1;
    stack.push(i);
    for &j in &child_ix[i] {
        match color[j] {
            0 => find_cycle(j, templates, child_ix, color, stack)?,
            1 => {
                let pos = stack.iter().position(|&x| x == j).unwrap();
                let mut names: Vec<&str> =
                    stack[pos..].iter().map(|&x| templates[x].name.as_str()).collect();
                names.push(templates[j].name.as_str());
                bail!("template instantiation cycle: {}", names.join(" -> "));
            }
            _ => {}
        }
    }
    stack.pop();
    color[i] = 2;
    Ok(())
}

/// Bottom-up address-window size of one instance of template `i`: the
/// footprint of its slaves and stacked child windows, or the explicit
/// `size` when that is at least the footprint. All arithmetic checked —
/// a wrap here is a config error, not a silent truncation.
fn window_of(
    i: usize,
    templates: &[TemplateCfg],
    child_ix: &[Vec<usize>],
    memo: &mut [Option<u64>],
) -> Result<u64> {
    if let Some(w) = memo[i] {
        return Ok(w);
    }
    let t = &templates[i];
    let ctx = format!("template[{}]", t.name);
    let mut fp: u64 = 0;
    for sc in &t.slaves {
        if sc.size == 0 {
            bail!("{ctx}.slave {}: size must be nonzero", sc.name);
        }
        let end = match sc.base.checked_add(sc.size) {
            Some(e) => e,
            None => bail!(
                "{ctx}.slave {}: base {:#x} + size {:#x} wraps the 64-bit address space",
                sc.name,
                sc.base,
                sc.size
            ),
        };
        fp = fp.max(end);
    }
    for (c, cc) in t.children.iter().enumerate() {
        let w = window_of(child_ix[i][c], templates, child_ix, memo)?;
        let stride = cc.stride.unwrap_or(w);
        let end = stride
            .checked_mul(cc.count as u64 - 1)
            .and_then(|s| cc.base.checked_add(s))
            .and_then(|b| b.checked_add(w));
        let end = match end {
            Some(e) => e,
            None => bail!(
                "{ctx}.child[{c}] ({}): stacked address range wraps the 64-bit space",
                cc.name
            ),
        };
        fp = fp.max(end);
    }
    let w = match t.size {
        Some(s) if s < fp => {
            bail!("{ctx}: size {s:#x} is smaller than the contents footprint {fp:#x}")
        }
        Some(s) => s,
        None => fp,
    };
    memo[i] = Some(w);
    Ok(w)
}

/// Pairwise-disjointness of everything mapped inside one template: slave
/// ranges and each stamped child instance's window. Catches both plain
/// slave collisions and `stride < window` stacking, with instance names
/// in the message. (The arithmetic was bounds-checked by [`window_of`].)
fn check_overlaps(t: &TemplateCfg, child_ix: &[usize], window: &[u64]) -> Result<()> {
    let mut ranges: Vec<(String, u64, u64)> = Vec::new();
    for sc in &t.slaves {
        ranges.push((format!("slave {}", sc.name), sc.base, sc.base + sc.size));
    }
    for (c, cc) in t.children.iter().enumerate() {
        let w = window[child_ix[c]];
        if w == 0 {
            continue;
        }
        let stride = cc.stride.unwrap_or(w);
        for k in 0..cc.count {
            let name = if cc.count > 1 {
                format!("child instance {}{k}", cc.name)
            } else {
                format!("child instance {}", cc.name)
            };
            let b = cc.base + stride * k as u64;
            ranges.push((name, b, b + w));
        }
    }
    for (a, ra) in ranges.iter().enumerate() {
        for rb in &ranges[..a] {
            if rb.1 < ra.2 && ra.1 < rb.2 {
                bail!(
                    "template[{}]: {} [{:#x}, {:#x}) and {} [{:#x}, {:#x}) overlap",
                    t.name,
                    rb.0,
                    rb.1,
                    rb.2,
                    ra.0,
                    ra.1,
                    ra.2
                );
            }
        }
    }
    Ok(())
}

/// (generators, slaves, instances) stamped by one instance of template
/// `i`, transitively. Saturating: the counts only gate validation.
fn totals_of(
    i: usize,
    templates: &[TemplateCfg],
    child_ix: &[Vec<usize>],
    memo: &mut [Option<(u64, u64, u64)>],
) -> (u64, u64, u64) {
    if let Some(v) = memo[i] {
        return v;
    }
    let t = &templates[i];
    let mut v = (t.masters.len() as u64, t.slaves.len() as u64, 1u64);
    for (c, cc) in t.children.iter().enumerate() {
        let cv = totals_of(child_ix[i][c], templates, child_ix, memo);
        let n = cc.count as u64;
        v.0 = v.0.saturating_add(n.saturating_mul(cv.0));
        v.1 = v.1.saturating_add(n.saturating_mul(cv.1));
        v.2 = v.2.saturating_add(n.saturating_mul(cv.2));
    }
    memo[i] = Some(v);
    v
}

/// Where a level's components register: shard 0 / the single arena
/// (root infrastructure), or a specific shard (a top-level instance's
/// subtree, or a root master island).
#[derive(Clone, Copy)]
enum Place {
    Root,
    Shard(usize),
}

/// The trunk ends a parent hands to a child level: the last downlink
/// bundle's slave end (the child crossbar's final slave port) and the
/// first uplink bundle's master end (its final master port).
struct ParentLink {
    down: SlaveEnd,
    up: MasterEnd,
}

/// Recursive instantiation state.
struct Walk<'a> {
    cfg: &'a TopoCfg,
    res: &'a Resolved,
    arena: Arena,
    epoch: Cycle,
    /// Memoized extra clock domains, keyed by (shard, period). In
    /// single-arena mode all shards share one engine, so the key
    /// collapses to (0, period).
    domains: HashMap<(usize, Ps), DomainId>,
    gens: Vec<Rc<RefCell<RwGen>>>,
    monitors: Vec<Rc<RefCell<Monitor>>>,
    taps: Vec<SlaveTap>,
    /// Global master walk index — the seed schedule (`0xC0FFEE + idx`)
    /// follows declaration order, like the flat builder.
    seed_idx: u64,
    /// Next shard for a top-level child instance (after shard 0 and the
    /// root master islands).
    next_top_shard: usize,
}

impl Walk<'_> {
    fn sharded(&self) -> bool {
        self.arena.threads() > 0
    }

    fn domain(&mut self, shard: usize, ps: Ps) -> DomainId {
        if ps == ROOT_CLOCK_PS {
            return self.arena.base_domain(shard);
        }
        let key = (if self.sharded() { shard } else { 0 }, ps);
        if let Some(&d) = self.domains.get(&key) {
            return d;
        }
        let d = self.arena.add_clock(shard, &format!("clk{ps}"), ps);
        self.domains.insert(key, d);
        d
    }

    /// Register `c` in `shard`'s clock-`ps` domain.
    fn add(&mut self, shard: usize, ps: Ps, c: Box<dyn Component>) {
        let d = self.domain(shard, ps);
        // SAFETY: the walk cuts every trunk bundle that crosses a shard
        // boundary (`register_cut`) before handing its far end to the
        // other side, and all other bundles connect components the walk
        // places in the same shard — so no channel `Rc` registered here
        // is reachable from another shard.
        unsafe { self.arena.add_in(shard, d, c) }
    }

    fn register_cut(&mut self, c: BundleCut, from: usize, to: usize) {
        match &mut self.arena {
            // SAFETY: the cut is the shard boundary itself; the walk
            // placed the producer-side bundle in `from` and hands the
            // relayed far end to components of `to` only.
            Arena::Sharded { eng } => unsafe {
                c.register(eng, from, to);
            },
            Arena::Single { .. } => unreachable!("cuts only exist in sharded mode"),
        }
    }

    /// Instantiate one level: masters (with monitors), child trunks and
    /// their subtrees, slaves, then the level's crossbar. Registration
    /// order is part of the determinism contract with the flat builder.
    fn level(
        &mut self,
        t_ix: usize,
        prefix: &str,
        base_abs: u64,
        clock_ps: Ps,
        place: Place,
        parent_link: Option<ParentLink>,
    ) -> Result<()> {
        let cfg = self.cfg;
        let res = self.res;
        let t = &cfg.templates[t_ix];
        let n_sp = n_slave_ports(t, parent_link.is_some());
        let s_cfg = BundleCfg::new(t.data_bits, t.id_bits);
        let m_cfg = BundleCfg::new(t.data_bits, xbar_master_id_bits(t.id_bits, n_sp));
        let shard = match place {
            Place::Root => 0,
            Place::Shard(s) => s,
        };

        let mut xbar_slaves: Vec<SlaveEnd> = Vec::new();
        let mut xbar_masters: Vec<MasterEnd> = Vec::new();
        let mut rules: Vec<AddrRule> = Vec::new();

        // Masters → monitors → crossbar slave ports.
        for (i, tm) in t.masters.iter().enumerate() {
            let label = format!("{prefix}{}", tm.cfg.name);
            let (gen_m, gen_s) = bundle(&format!("{label}.port"), s_cfg);
            let (mon_m, mon_s) = bundle(&format!("{label}.mon"), s_cfg);
            let mut mc = tm.cfg.clone();
            if !tm.global {
                mc.base = match base_abs.checked_add(mc.base) {
                    Some(b) => b,
                    None => bail!("master {label}: local base wraps the 64-bit address space"),
                };
            }
            let seed = self.seed_idx;
            self.seed_idx += 1;
            let (g, g_ad) = shared(RwGen::new(label.clone(), gen_m, gen_cfg(&mc, &s_cfg, seed)?));
            self.gens.push(g);
            let (mon, mon_ad) = shared(Monitor::new(format!("{label}.monitor"), gen_s, mon_m));
            self.monitors.push(mon);
            if matches!(place, Place::Root) && self.sharded() {
                // Root master islands shard exactly like the flat
                // builder: generator + monitor in shard 1 + i, the
                // output bundle cut toward the crossbar in shard 0.
                let island = 1 + i;
                self.add(island, clock_ps, Box::new(g_ad));
                self.add(island, clock_ps, Box::new(mon_ad));
                let (c, far) = cut_slave_export(&format!("cut.{label}"), s_cfg, mon_s, self.epoch);
                self.register_cut(c, island, 0);
                xbar_slaves.push(far);
            } else {
                self.add(shard, clock_ps, Box::new(g_ad));
                self.add(shard, clock_ps, Box::new(mon_ad));
                xbar_slaves.push(mon_s);
            }
        }

        // Child instances: downlink trunk, uplink trunk, then recurse.
        for (c, cc) in t.children.iter().enumerate() {
            let j = res.child_ix[t_ix][c];
            let ct = &cfg.templates[j];
            let window = res.window[j];
            let stride = cc.stride.unwrap_or(window);
            let child_clock = ct.clock_ps.unwrap_or(clock_ps);
            let child_s_cfg = BundleCfg::new(ct.data_bits, ct.id_bits);
            let child_id_bits = xbar_master_id_bits(ct.id_bits, n_slave_ports(ct, true));
            let child_m_cfg = BundleCfg::new(ct.data_bits, child_id_bits);
            for k in 0..cc.count {
                let inst = if cc.count > 1 { format!("{}{k}", cc.name) } else { cc.name.clone() };
                let cp = format!("{prefix}{inst}.");
                let inst_base = base_abs + cc.base + stride * k as u64;
                let (child_place, child_shard, cut_trunk) =
                    if matches!(place, Place::Root) && self.sharded() {
                        let s = self.next_top_shard;
                        self.next_top_shard += 1;
                        (Place::Shard(s), s, true)
                    } else {
                        (place, shard, false)
                    };

                // Downlink: parent crossbar master port → [cut] →
                // width → CDC → ID → child crossbar slave port.
                let (down_m, down_s) = bundle(&format!("{cp}down"), m_cfg);
                if window > 0 {
                    rules.push(AddrRule::new(inst_base, inst_base + window, xbar_masters.len()));
                }
                xbar_masters.push(down_m);
                let mut prev = down_s;
                let mut cur = m_cfg;
                if cut_trunk {
                    let (cut, far) =
                        cut_slave_export(&format!("cut.{cp}down"), cur, prev, self.epoch);
                    self.register_cut(cut, 0, child_shard);
                    prev = far;
                }
                if ct.data_bits != t.data_bits {
                    let dw = BundleCfg::new(ct.data_bits, cur.id_bits);
                    let (dw_m, dw_s) = bundle(&format!("{cp}down.dw"), dw);
                    let conv: Box<dyn Component> = if ct.data_bits > t.data_bits {
                        Box::new(Upsizer::new(format!("{cp}down.up"), prev, dw_m, 1))
                    } else {
                        Box::new(Downsizer::new(format!("{cp}down.dn"), prev, dw_m))
                    };
                    self.add(child_shard, clock_ps, conv);
                    prev = dw_s;
                    cur = dw;
                }
                if child_clock != clock_ps {
                    let label = format!("{cp}down.cdc");
                    let (cdc_m, cdc_s) = bundle(&label, cur);
                    let (near, far) = cdc(&label, prev, cdc_m, clock_ps, child_clock, CDC_DEPTH);
                    self.add(child_shard, clock_ps, Box::new(near));
                    self.add(child_shard, child_clock, Box::new(far));
                    prev = cdc_s;
                }
                // The parent's prepend bits never fit the child's ID
                // space: the ID boundary stage is unconditional.
                let (id_m, id_s) = bundle(&format!("{cp}down.id"), child_s_cfg);
                let u = 1usize << cur.id_bits.min(ct.id_bits);
                let conv: Box<dyn Component> = match cc.id_policy {
                    IdPolicy::Remap => Box::new(IdRemap::new(
                        format!("{cp}down.remap"),
                        prev,
                        id_m,
                        u,
                        TXNS_PER_ID,
                    )),
                    IdPolicy::Serialize => Box::new(IdSerialize::new(
                        format!("{cp}down.ser"),
                        prev,
                        id_m,
                        u,
                        TXNS_PER_ID as usize,
                    )),
                };
                self.add(child_shard, child_clock, conv);

                // Uplink: child crossbar master port → CDC → width →
                // ID → [cut] → parent crossbar slave port.
                let (up_m, up_s) = bundle(&format!("{cp}up"), child_m_cfg);
                let mut prev = up_s;
                let mut cur = child_m_cfg;
                if child_clock != clock_ps {
                    let (cdc_m, cdc_s) = bundle(&format!("{cp}up.cdc"), cur);
                    let (near, far) =
                        cdc(&format!("{cp}up.cdc"), prev, cdc_m, child_clock, clock_ps, CDC_DEPTH);
                    self.add(child_shard, child_clock, Box::new(near));
                    self.add(child_shard, clock_ps, Box::new(far));
                    prev = cdc_s;
                }
                if ct.data_bits != t.data_bits {
                    let uw = BundleCfg::new(t.data_bits, cur.id_bits);
                    let (uw_m, uw_s) = bundle(&format!("{cp}up.dw"), uw);
                    let conv: Box<dyn Component> = if t.data_bits > ct.data_bits {
                        Box::new(Upsizer::new(format!("{cp}up.up"), prev, uw_m, 1))
                    } else {
                        Box::new(Downsizer::new(format!("{cp}up.dn"), prev, uw_m))
                    };
                    self.add(child_shard, clock_ps, conv);
                    prev = uw_s;
                    cur = uw;
                }
                let (uid_m, uid_s) = bundle(&format!("{cp}up.id"), s_cfg);
                let u = 1usize << cur.id_bits.min(t.id_bits);
                let conv: Box<dyn Component> = match cc.id_policy {
                    IdPolicy::Remap => {
                        Box::new(IdRemap::new(format!("{cp}up.remap"), prev, uid_m, u, TXNS_PER_ID))
                    }
                    IdPolicy::Serialize => Box::new(IdSerialize::new(
                        format!("{cp}up.ser"),
                        prev,
                        uid_m,
                        u,
                        TXNS_PER_ID as usize,
                    )),
                };
                self.add(child_shard, clock_ps, conv);
                let mut up_far = uid_s;
                if cut_trunk {
                    let (cut, far) =
                        cut_slave_export(&format!("cut.{cp}up"), s_cfg, up_far, self.epoch);
                    self.register_cut(cut, child_shard, 0);
                    up_far = far;
                }
                xbar_slaves.push(up_far);

                self.level(
                    j,
                    &cp,
                    inst_base,
                    child_clock,
                    child_place,
                    Some(ParentLink { down: id_s, up: up_m }),
                )?;
            }
        }

        // Slaves → crossbar master ports.
        for sc in &t.slaves {
            let label = format!("{prefix}{}", sc.name);
            let abs = base_abs + sc.base;
            let (m, s) = bundle(&format!("{label}.port"), m_cfg);
            self.taps.push(SlaveTap::new(label.clone(), &m));
            rules.push(AddrRule::new(abs, abs + sc.size, xbar_masters.len()));
            xbar_masters.push(m);
            let ep: Box<dyn Component> = match &sc.kind {
                SlaveKind::Perfect { latency } => Box::new(PerfectSlave::new(label, s, *latency)),
                SlaveKind::Simplex { latency } => Box::new(MemSimplex::new(
                    label,
                    s,
                    Sram::new(abs, sc.size as usize, *latency),
                    ArbPolicy::RoundRobin,
                )),
                SlaveKind::Duplex { banks, latency } => Box::new(MemDuplex::new(
                    label,
                    s,
                    BankArray::new(
                        abs,
                        (sc.size as usize).div_ceil(*banks),
                        *banks,
                        m_cfg.beat_bytes(),
                        *latency,
                    ),
                )),
            };
            self.add(shard, clock_ps, ep);
        }

        // Parent trunk ports last; everything outside this instance's
        // window routes up, in-window holes DECERR locally (so a hole
        // can never ping-pong between parent and child maps).
        if let Some(link) = parent_link {
            xbar_slaves.push(link.down);
            let up = xbar_masters.len();
            xbar_masters.push(link.up);
            let end = base_abs + res.window[t_ix];
            if base_abs > 0 {
                rules.push(AddrRule::new(0, base_abs, up));
            }
            if end < u64::MAX {
                rules.push(AddrRule::new(end, u64::MAX, up));
            }
        }
        let map = AddrMap::new(rules, DefaultPort::Error);
        let n = xbar_slaves.len();
        let xbar = Xbar::new(
            format!("{prefix}xbar"),
            xbar_slaves,
            xbar_masters,
            XbarCfg {
                slave_cfg: s_cfg,
                maps: vec![map; n],
                max_txns_per_id: TXNS_PER_ID,
                pipeline: t.pipeline,
            },
        );
        for part in xbar.into_parts() {
            self.add(shard, clock_ps, part);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const NESTED: &str = r#"
[topology]
root = "chip"
cycles = 4000

[[template]]
name = "cluster"
data_bits = 64
id_bits = 4

[[template.master]]
name = "core"
span = 0x1000
total = 40

[[template.slave]]
name = "l1"
kind = "simplex"
base = 0x0
size = 0x1000

[[template]]
name = "chip"
data_bits = 64
id_bits = 4

[[template.master]]
name = "dma"
base = 0x2000
span = 0x1000
total = 30

[[template.child]]
template = "cluster"
count = 2
base = 0x0

[[template.slave]]
name = "l2"
base = 0x2000
size = 0x1000
"#;

    #[test]
    fn parses_nested_templates() {
        let cfg = TopoCfg::from_str_toml(NESTED).unwrap();
        assert_eq!(cfg.root, "chip");
        assert_eq!(cfg.cycles, 4000);
        assert_eq!(cfg.templates.len(), 2);
        let chip = &cfg.templates[1];
        assert_eq!(chip.children.len(), 1);
        assert_eq!(chip.children[0].count, 2);
        assert_eq!(chip.children[0].name, "cluster");
        assert!(chip.children[0].converters);
        assert_eq!(chip.children[0].id_policy, IdPolicy::Remap);
    }

    #[test]
    fn windows_stack_child_instances() {
        let cfg = TopoCfg::from_str_toml(NESTED).unwrap();
        let res = cfg.resolve().unwrap();
        // cluster window = its L1; chip = 2 stacked clusters + l2.
        assert_eq!(res.window[0], 0x1000);
        assert_eq!(res.window[1], 0x3000);
    }

    #[test]
    fn scope_and_policy_keys_are_validated() {
        let bad = NESTED.replace("name = \"core\"", "name = \"core\"\nscope = \"sideways\"");
        let err = TopoCfg::from_str_toml(&bad).unwrap_err().to_string();
        assert!(err.contains("scope"), "{err}");
        let bad = NESTED.replace("count = 2", "count = 2\nid_policy = \"fold\"");
        let err = TopoCfg::from_str_toml(&bad).unwrap_err().to_string();
        assert!(err.contains("id_policy"), "{err}");
    }

    #[test]
    fn explicit_size_must_cover_footprint() {
        let bad = NESTED.replace("name = \"chip\"", "name = \"chip\"\nsize = 0x2000");
        let cfg = TopoCfg::from_str_toml(&bad).unwrap();
        let err = cfg.resolve().unwrap_err().to_string();
        assert!(err.contains("footprint"), "{err}");
    }

    #[test]
    fn nested_build_runs_clean() {
        let cfg = TopoCfg::from_str_toml(NESTED).unwrap();
        let mut sys = cfg.build().unwrap();
        assert!(sys.run(cfg.cycles), "all traffic must complete");
        assert!(sys.check_protocol().is_empty());
        // 2 cluster cores * 40 transactions + the chip-level DMA's 30.
        let total: u64 = sys.gens.iter().map(|g| g.borrow().stats.completed).sum();
        assert_eq!(total, 110);
        // Local traffic lands on each instance's own L1, the DMA on L2.
        for tap in &sys.slave_taps {
            assert!(tap.data_bytes() > 0, "{} saw no traffic", tap.name);
        }
    }
}
