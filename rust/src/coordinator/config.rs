//! Configuration system: a hand-rolled TOML-subset parser (crates.io is
//! unreachable offline, so `toml`/`serde` are reimplemented at the scale
//! we need) plus the typed simulation config.
//!
//! Supported TOML subset: `[section]`, `[[array-of-tables]]`,
//! `key = value` with integers (decimal/hex), floats, booleans, strings,
//! and `#` comments — which covers the whole config surface.

use std::collections::HashMap;

use crate::bail;
use crate::errors::{Context, Result};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as u64),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// One table of key/values.
pub type Table = HashMap<String, Value>;

/// Parsed document: singleton tables and arrays-of-tables.
#[derive(Debug, Default)]
pub struct Doc {
    pub tables: HashMap<String, Table>,
    pub arrays: HashMap<String, Vec<Table>>,
}

impl Doc {
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.get(name)
    }

    pub fn array(&self, name: &str) -> &[Table] {
        self.arrays.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    // TOML allows `_` separators in every numeric literal (ints, hex,
    // floats alike); normalize once before classifying, so `2_000.5`
    // parses the same as `2_000`.
    let num = s.replace('_', "");
    if let Some(hex) = num.strip_prefix("0x").or_else(|| num.strip_prefix("0X")) {
        return Ok(Value::Int(i64::from_str_radix(hex, 16).context("bad hex literal")?));
    }
    if num.contains('.') || num.contains('e') || num.contains('E') {
        if let Ok(f) = num.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = num.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    bail!("cannot parse value: {s}")
}

/// Parse the TOML subset.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    // Current insertion point: either a named singleton or the last element
    // of a named array.
    enum Cur {
        None,
        Table(String),
        Array(String),
    }
    let mut cur = Cur::None;
    for (ln, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // Don't strip '#' inside strings: the subset forbids '#' in
            // strings to keep the parser trivial.
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| crate::anyhow!("line {}: {m}: {raw}", ln + 1);
        if let Some(name) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
            let name = name.trim().to_string();
            doc.arrays.entry(name.clone()).or_default().push(Table::new());
            cur = Cur::Array(name);
        } else if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            let name = name.trim().to_string();
            doc.tables.entry(name.clone()).or_default();
            cur = Cur::Table(name);
        } else if let Some(eq) = line.find('=') {
            let key = line[..eq].trim().to_string();
            let val = parse_value(&line[eq + 1..]).map_err(|e| err(&e.to_string()))?;
            match &cur {
                Cur::None => bail!(err("key outside any section")),
                Cur::Table(t) => {
                    doc.tables.get_mut(t).unwrap().insert(key, val);
                }
                Cur::Array(a) => {
                    doc.arrays.get_mut(a).unwrap().last_mut().unwrap().insert(key, val);
                }
            }
        } else {
            bail!(err("unrecognized line"));
        }
    }
    Ok(doc)
}

// ---------------------------------------------------------------------------
// Typed simulation config
// ---------------------------------------------------------------------------

/// Endpoint kinds attachable to crossbar master ports.
#[derive(Debug, Clone, PartialEq)]
pub enum SlaveKind {
    /// Pattern-answering endpoint with fixed latency.
    Perfect { latency: u64 },
    /// Simplex on-chip memory controller over a single SRAM.
    Simplex { latency: u64 },
    /// Duplex memory controller with `banks` interleaved banks.
    Duplex { banks: usize, latency: u64 },
}

#[derive(Debug, Clone)]
pub struct MasterCfg {
    pub name: String,
    pub pattern: String,
    pub base: u64,
    pub span: u64,
    pub p_read: f64,
    pub beats: usize,
    pub total: Option<u64>,
    pub max_outstanding: usize,
    pub n_ids: u32,
    /// Hotspot pattern: fraction of accesses that hit the hot window.
    pub p_hot: f64,
    /// Hotspot pattern: hot window size in bytes. `None` = builder default
    /// (clamped to `span` either way, so the window stays decodable).
    pub hot_span: Option<u64>,
}

#[derive(Debug, Clone)]
pub struct SlaveCfg {
    pub name: String,
    pub kind: SlaveKind,
    /// Address range this slave serves (crossbar rule).
    pub base: u64,
    pub size: u64,
}

/// A single-crossbar topology: the config surface of `noc simulate`.
#[derive(Debug, Clone)]
pub struct SimCfg {
    pub cycles: u64,
    pub data_bits: usize,
    pub id_bits: usize,
    pub pipeline: bool,
    /// Disable the engine's sleep/wake tracking: tick every component on
    /// every cycle (the pre-engine behaviour). Kept as an A/B oracle —
    /// results must be bit-identical to event mode.
    pub full_scan: bool,
    /// Worker threads for the sharded engine (`noc simulate --threads`).
    /// `Some(0)` = the single-arena engine; `Some(N >= 1)` shards every
    /// master island off the crossbar behind epoch-exchange cuts and
    /// drives the shards with `N` threads — results are bit-identical
    /// for every `N >= 1`. `None` = unset: library callers get the
    /// single-arena engine, while the CLI auto-picks the host core count
    /// (`sim::auto_threads`; `--threads 0` stays the explicit
    /// single-arena escape hatch).
    pub threads: Option<usize>,
    /// Exchange epoch in cycles (sharded mode only).
    pub epoch: u64,
    pub masters: Vec<MasterCfg>,
    pub slaves: Vec<SlaveCfg>,
}

impl SimCfg {
    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let sim = doc.table("sim").context("missing [sim] section")?;
        let get_u64 = |t: &Table, k: &str, d: u64| -> Result<u64> {
            t.get(k).map(|v| v.as_u64()).transpose().map(|o| o.unwrap_or(d))
        };
        let cycles = get_u64(sim, "cycles", 10_000)?;
        let data_bits = sim.get("data_bits").map(|v| v.as_usize()).transpose()?.unwrap_or(64);
        let id_bits = sim.get("id_bits").map(|v| v.as_usize()).transpose()?.unwrap_or(4);
        let pipeline = sim.get("pipeline").map(|v| v.as_bool()).transpose()?.unwrap_or(false);
        let full_scan = sim.get("full_scan").map(|v| v.as_bool()).transpose()?.unwrap_or(false);
        let threads = sim.get("threads").map(|v| v.as_usize()).transpose()?;
        let epoch = get_u64(sim, "epoch", 8)?;
        if epoch == 0 {
            bail!("epoch must be at least 1 cycle");
        }

        let mut masters = Vec::new();
        for (i, t) in doc.array("master").iter().enumerate() {
            let p_hot = t.get("p_hot").map(|v| v.as_f64()).transpose()?.unwrap_or(0.5);
            if !(0.0..=1.0).contains(&p_hot) {
                bail!("master {i}: p_hot must be within [0, 1], got {p_hot}");
            }
            masters.push(MasterCfg {
                name: t
                    .get("name")
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?
                    .unwrap_or(format!("m{i}")),
                pattern: t
                    .get("pattern")
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?
                    .unwrap_or("uniform".into()),
                base: get_u64(t, "base", 0)?,
                span: get_u64(t, "span", 0x1_0000)?,
                p_read: t.get("reads").map(|v| v.as_f64()).transpose()?.unwrap_or(0.5),
                beats: t.get("beats").map(|v| v.as_usize()).transpose()?.unwrap_or(1),
                total: t.get("total").map(|v| v.as_u64()).transpose()?,
                max_outstanding: t
                    .get("max_outstanding")
                    .map(|v| v.as_usize())
                    .transpose()?
                    .unwrap_or(4),
                n_ids: t.get("ids").map(|v| v.as_u64()).transpose()?.unwrap_or(1) as u32,
                p_hot,
                hot_span: t.get("hot_span").map(|v| v.as_u64()).transpose()?,
            });
        }
        let mut slaves = Vec::new();
        for (i, t) in doc.array("slave").iter().enumerate() {
            let latency = get_u64(t, "latency", 2)?;
            let kind = match t.get("kind").map(|v| v.as_str()).transpose()?.unwrap_or("perfect") {
                "perfect" => SlaveKind::Perfect { latency },
                "simplex" => SlaveKind::Simplex { latency },
                "duplex" => SlaveKind::Duplex {
                    banks: t.get("banks").map(|v| v.as_usize()).transpose()?.unwrap_or(2),
                    latency,
                },
                k => bail!("unknown slave kind: {k}"),
            };
            slaves.push(SlaveCfg {
                name: t
                    .get("name")
                    .map(|v| v.as_str().map(String::from))
                    .transpose()?
                    .unwrap_or(format!("s{i}")),
                kind,
                base: get_u64(t, "base", (i as u64) * 0x1_0000)?,
                size: get_u64(t, "size", 0x1_0000)?,
            });
        }
        if masters.is_empty() || slaves.is_empty() {
            bail!("config needs at least one [[master]] and one [[slave]]");
        }
        Ok(SimCfg {
            cycles,
            data_bits,
            id_bits,
            pipeline,
            full_scan,
            threads,
            epoch,
            masters,
            slaves,
        })
    }

    pub fn from_str_toml(text: &str) -> Result<Self> {
        Self::from_doc(&parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# quickstart topology
[sim]
cycles = 5000
data_bits = 64
id_bits = 4
pipeline = true

[[master]]
name = "gen0"
pattern = "uniform"
base = 0x0
span = 0x2_0000
reads = 0.7
total = 500

[[master]]
name = "gen1"
beats = 4

[[slave]]
name = "mem0"
kind = "duplex"
banks = 4
base = 0x0
size = 0x1_0000

[[slave]]
name = "mem1"
kind = "perfect"
latency = 10
base = 0x1_0000
size = 0x1_0000
"#;

    #[test]
    fn parses_example() {
        let cfg = SimCfg::from_str_toml(EXAMPLE).unwrap();
        assert_eq!(cfg.cycles, 5000);
        assert!(cfg.pipeline);
        assert_eq!(cfg.masters.len(), 2);
        assert_eq!(cfg.slaves.len(), 2);
        assert_eq!(cfg.masters[0].name, "gen0");
        assert_eq!(cfg.masters[0].span, 0x2_0000);
        assert!((cfg.masters[0].p_read - 0.7).abs() < 1e-9);
        assert_eq!(cfg.masters[1].beats, 4);
        assert_eq!(cfg.slaves[0].kind, SlaveKind::Duplex { banks: 4, latency: 2 });
        assert_eq!(cfg.slaves[1].kind, SlaveKind::Perfect { latency: 10 });
        assert_eq!(cfg.slaves[1].base, 0x1_0000);
    }

    #[test]
    fn value_types() {
        assert_eq!(parse_value("42").unwrap(), Value::Int(42));
        assert_eq!(parse_value("0x1F").unwrap(), Value::Int(31));
        assert_eq!(parse_value("2.5").unwrap(), Value::Float(2.5));
        assert_eq!(parse_value("true").unwrap(), Value::Bool(true));
        assert_eq!(parse_value("\"hi\"").unwrap(), Value::Str("hi".into()));
        assert!(parse_value("nope nope").is_err());
    }

    #[test]
    fn underscore_separators_in_all_numeric_literals() {
        assert_eq!(parse_value("2_000").unwrap(), Value::Int(2000));
        assert_eq!(parse_value("0x1_F").unwrap(), Value::Int(31));
        // Floats take underscores too (previously rejected).
        assert_eq!(parse_value("2_000.5").unwrap(), Value::Float(2000.5));
        assert_eq!(parse_value("1_0e2").unwrap(), Value::Float(1000.0));
        // Strings keep their underscores verbatim.
        assert_eq!(parse_value("\"a_b\"").unwrap(), Value::Str("a_b".into()));
    }

    #[test]
    fn hotspot_and_engine_keys_parse() {
        let text = EXAMPLE
            .replace(
                "pattern = \"uniform\"",
                "pattern = \"hotspot\"\np_hot = 0.8\nhot_span = 0x800",
            )
            .replace("[sim]", "[sim]\nfull_scan = true");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert!(cfg.full_scan);
        assert!((cfg.masters[0].p_hot - 0.8).abs() < 1e-9);
        assert_eq!(cfg.masters[0].hot_span, Some(0x800));
        // Defaults on the second master.
        assert!((cfg.masters[1].p_hot - 0.5).abs() < 1e-9);
        assert_eq!(cfg.masters[1].hot_span, None);
    }

    #[test]
    fn threads_and_epoch_keys_parse_with_defaults() {
        let cfg = SimCfg::from_str_toml(EXAMPLE).unwrap();
        assert_eq!(cfg.threads, None, "unset: library default is single-arena, CLI auto-picks");
        assert_eq!(cfg.epoch, 8);
        let text = EXAMPLE.replace("[sim]", "[sim]\nthreads = 4\nepoch = 16");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert_eq!(cfg.threads, Some(4));
        assert_eq!(cfg.epoch, 16);
        let text = EXAMPLE.replace("[sim]", "[sim]\nthreads = 0");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert_eq!(cfg.threads, Some(0), "explicit 0 = single-arena");
    }

    #[test]
    fn rejects_zero_epoch() {
        let text = EXAMPLE.replace("[sim]", "[sim]\nepoch = 0");
        assert!(SimCfg::from_str_toml(&text).is_err());
    }

    #[test]
    fn rejects_out_of_range_p_hot() {
        let text = EXAMPLE.replace("pattern = \"uniform\"", "pattern = \"hotspot\"\np_hot = 1.5");
        assert!(SimCfg::from_str_toml(&text).is_err());
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = parse("# top\n[sim]\n# inner\ncycles = 1 # trailing\n").unwrap();
        assert_eq!(doc.table("sim").unwrap()["cycles"], Value::Int(1));
    }

    #[test]
    fn rejects_key_outside_section() {
        assert!(parse("cycles = 1").is_err());
    }

    #[test]
    fn missing_sections_fail_typed_parse() {
        assert!(SimCfg::from_str_toml("[sim]\ncycles = 1").is_err());
    }
}
