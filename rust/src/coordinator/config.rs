//! Configuration system: a hand-rolled TOML-subset parser (crates.io is
//! unreachable offline, so `toml`/`serde` are reimplemented at the scale
//! we need) plus the typed simulation config.
//!
//! Supported TOML subset: `[section]`, `[[array-of-tables]]`, *scoped*
//! arrays-of-tables (`[[template.master]]` attaches to the most recent
//! `[[template]]` — the topology grammar's nesting), `key = value` with
//! integers (decimal/hex), floats, booleans, strings, and `#` comments —
//! which covers the whole config surface.
//!
//! Typed access goes through [`Table::get_or`] / [`Table::get_opt`] /
//! [`Table::require`], which carry a field-path context so a bad value
//! surfaces as `"template[cluster].master[2].beats: expected
//! non-negative integer, ..."` instead of a bare type error.

use std::collections::HashMap;

use crate::bail;
use crate::errors::{Context, Result};
use crate::sim::{EngineOpts, EpochPolicy};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as u64),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Conversion out of a parsed [`Value`], for the typed [`Table`]
/// accessors. Implemented for the config surface's primitive types.
pub trait FromValue: Sized {
    fn from_value(v: &Value) -> Result<Self>;
}

impl FromValue for u64 {
    fn from_value(v: &Value) -> Result<u64> {
        v.as_u64()
    }
}

impl FromValue for usize {
    fn from_value(v: &Value) -> Result<usize> {
        v.as_usize()
    }
}

impl FromValue for u32 {
    fn from_value(v: &Value) -> Result<u32> {
        let x = v.as_u64()?;
        u32::try_from(x).map_err(|_| crate::anyhow!("expected 32-bit integer, got {x}"))
    }
}

impl FromValue for f64 {
    fn from_value(v: &Value) -> Result<f64> {
        v.as_f64()
    }
}

impl FromValue for bool {
    fn from_value(v: &Value) -> Result<bool> {
        v.as_bool()
    }
}

impl FromValue for String {
    fn from_value(v: &Value) -> Result<String> {
        v.as_str().map(String::from)
    }
}

fn key_path(ctx: &str, key: &str) -> String {
    if ctx.is_empty() {
        key.to_string()
    } else {
        format!("{ctx}.{key}")
    }
}

/// One table of key/values. Derefs to the underlying map, so raw
/// `get`/indexing still work; typed lookups should use the accessor
/// methods, which prefix errors with the field path.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Table(HashMap<String, Value>);

impl Table {
    pub fn new() -> Self {
        Table(HashMap::new())
    }

    /// Typed lookup with a default: `ctx` is the table's field path for
    /// error messages (e.g. `"template[cluster].master[2]"`).
    pub fn get_or<T: FromValue>(&self, ctx: &str, key: &str, default: T) -> Result<T> {
        Ok(self.get_opt(ctx, key)?.unwrap_or(default))
    }

    /// Typed lookup of an optional key: `None` when absent, `Err` with
    /// the field path when present but mistyped.
    pub fn get_opt<T: FromValue>(&self, ctx: &str, key: &str) -> Result<Option<T>> {
        match self.0.get(key) {
            None => Ok(None),
            Some(v) => T::from_value(v).map(Some).with_context(|| key_path(ctx, key)),
        }
    }

    /// Typed lookup of a mandatory key; absence is an error naming the
    /// field path.
    pub fn require<T: FromValue>(&self, ctx: &str, key: &str) -> Result<T> {
        match self.0.get(key) {
            None => bail!("{}: missing required key", key_path(ctx, key)),
            Some(v) => T::from_value(v).with_context(|| key_path(ctx, key)),
        }
    }
}

impl std::ops::Deref for Table {
    type Target = HashMap<String, Value>;
    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

impl std::ops::DerefMut for Table {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.0
    }
}

/// Parsed document: singleton tables and arrays-of-tables. Scoped
/// arrays (`[[a.b]]`) are stored under their full dotted name, with
/// `parents` recording which element of `[[a]]` each one attaches to.
#[derive(Debug, Default)]
pub struct Doc {
    pub tables: HashMap<String, Table>,
    pub arrays: HashMap<String, Vec<Table>>,
    /// For each scoped array name `"a.b"`: the index into `arrays["a"]`
    /// that owned each element at parse time (same length as
    /// `arrays["a.b"]`).
    pub parents: HashMap<String, Vec<usize>>,
}

impl Doc {
    pub fn table(&self, name: &str) -> Option<&Table> {
        self.tables.get(name)
    }

    pub fn array(&self, name: &str) -> &[Table] {
        self.arrays.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// The `[[{parent}.{child}]]` tables declared under element `idx` of
    /// `[[{parent}]]`, in declaration order.
    pub fn scoped(&self, parent: &str, idx: usize, child: &str) -> Vec<&Table> {
        let name = format!("{parent}.{child}");
        let Some(tables) = self.arrays.get(&name) else {
            return Vec::new();
        };
        let owners = self.parents.get(&name).map(|v| v.as_slice()).unwrap_or(&[]);
        tables.iter().zip(owners).filter(|&(_, &o)| o == idx).map(|(t, _)| t).collect()
    }
}

fn parse_value(s: &str) -> Result<Value> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    // TOML allows `_` separators in every numeric literal (ints, hex,
    // floats alike); normalize once before classifying, so `2_000.5`
    // parses the same as `2_000`.
    let num = s.replace('_', "");
    if let Some(hex) = num.strip_prefix("0x").or_else(|| num.strip_prefix("0X")) {
        return Ok(Value::Int(i64::from_str_radix(hex, 16).context("bad hex literal")?));
    }
    if num.contains('.') || num.contains('e') || num.contains('E') {
        if let Ok(f) = num.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    }
    if let Ok(i) = num.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    bail!("cannot parse value: {s}")
}

/// Parse the TOML subset.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    // Current insertion point: either a named singleton or the last element
    // of a named array.
    enum Cur {
        None,
        Table(String),
        Array(String),
    }
    let mut cur = Cur::None;
    for (ln, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            // Don't strip '#' inside strings: the subset forbids '#' in
            // strings to keep the parser trivial.
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| crate::anyhow!("line {}: {m}: {raw}", ln + 1);
        if let Some(name) = line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]")) {
            let name = name.trim().to_string();
            if let Some((parent, _)) = name.split_once('.') {
                // A scoped array element attaches to the most recent
                // element of its parent array.
                let owner = match doc.arrays.get(parent).map(|v| v.len()) {
                    Some(n) if n > 0 => n - 1,
                    _ => bail!(err(&format!("[[{name}]] before any [[{parent}]]"))),
                };
                doc.parents.entry(name.clone()).or_default().push(owner);
            }
            doc.arrays.entry(name.clone()).or_default().push(Table::new());
            cur = Cur::Array(name);
        } else if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            let name = name.trim().to_string();
            doc.tables.entry(name.clone()).or_default();
            cur = Cur::Table(name);
        } else if let Some(eq) = line.find('=') {
            let key = line[..eq].trim().to_string();
            let val = parse_value(&line[eq + 1..]).map_err(|e| err(&e.to_string()))?;
            match &cur {
                Cur::None => bail!(err("key outside any section")),
                Cur::Table(t) => {
                    doc.tables.get_mut(t).unwrap().insert(key, val);
                }
                Cur::Array(a) => {
                    doc.arrays.get_mut(a).unwrap().last_mut().unwrap().insert(key, val);
                }
            }
        } else {
            bail!(err("unrecognized line"));
        }
    }
    Ok(doc)
}

// ---------------------------------------------------------------------------
// Typed simulation config
// ---------------------------------------------------------------------------

impl EngineOpts {
    /// Parse the shared engine keys (`threads`, `epoch`, `epoch_policy`,
    /// `full_scan`) out of a config table — the one doc-parsing path for
    /// both the flat `[sim]` config and the grammar's `[topology]`
    /// section. Range validation is [`EngineOpts::validate`], shared
    /// with the CLI path.
    pub fn from_table(t: &Table, ctx: &str) -> Result<EngineOpts> {
        let defaults = EngineOpts::default();
        let policy = match t.get_opt::<String>(ctx, "epoch_policy")? {
            Some(s) => EpochPolicy::parse(&s).with_context(|| format!("{ctx}.epoch_policy"))?,
            None => defaults.policy,
        };
        let opts = EngineOpts {
            threads: t.get_opt(ctx, "threads")?,
            epoch: t.get_or(ctx, "epoch", defaults.epoch)?,
            policy,
            full_scan: t.get_or(ctx, "full_scan", defaults.full_scan)?,
        };
        opts.validate().with_context(|| format!("{ctx}: engine options"))?;
        Ok(opts)
    }
}

/// Endpoint kinds attachable to crossbar master ports.
#[derive(Debug, Clone, PartialEq)]
pub enum SlaveKind {
    /// Pattern-answering endpoint with fixed latency.
    Perfect { latency: u64 },
    /// Simplex on-chip memory controller over a single SRAM.
    Simplex { latency: u64 },
    /// Duplex memory controller with `banks` interleaved banks.
    Duplex { banks: usize, latency: u64 },
}

#[derive(Debug, Clone)]
pub struct MasterCfg {
    pub name: String,
    pub pattern: String,
    pub base: u64,
    pub span: u64,
    pub p_read: f64,
    pub beats: usize,
    pub total: Option<u64>,
    pub max_outstanding: usize,
    pub n_ids: u32,
    /// Hotspot pattern: fraction of accesses that hit the hot window.
    pub p_hot: f64,
    /// Hotspot pattern: hot window size in bytes. `None` = builder default
    /// (clamped to `span` either way, so the window stays decodable).
    pub hot_span: Option<u64>,
}

#[derive(Debug, Clone)]
pub struct SlaveCfg {
    pub name: String,
    pub kind: SlaveKind,
    /// Address range this slave serves (crossbar rule).
    pub base: u64,
    pub size: u64,
}

/// Parse one `[[master]]`-shaped table; `ctx` is the field path for
/// errors, `i` feeds the positional name default. Shared between the
/// flat config and the topology grammar's `[[template.master]]`.
pub(crate) fn master_from_table(t: &Table, ctx: &str, i: usize) -> Result<MasterCfg> {
    let p_hot = t.get_or(ctx, "p_hot", 0.5)?;
    if !(0.0..=1.0).contains(&p_hot) {
        bail!("{ctx}.p_hot: must be within [0, 1], got {p_hot}");
    }
    Ok(MasterCfg {
        name: t.get_or(ctx, "name", format!("m{i}"))?,
        pattern: t.get_or(ctx, "pattern", "uniform".to_string())?,
        base: t.get_or(ctx, "base", 0)?,
        span: t.get_or(ctx, "span", 0x1_0000)?,
        p_read: t.get_or(ctx, "reads", 0.5)?,
        beats: t.get_or(ctx, "beats", 1)?,
        total: t.get_opt(ctx, "total")?,
        max_outstanding: t.get_or(ctx, "max_outstanding", 4)?,
        n_ids: t.get_or(ctx, "ids", 1u32)?,
        p_hot,
        hot_span: t.get_opt(ctx, "hot_span")?,
    })
}

/// Parse one `[[slave]]`-shaped table (see [`master_from_table`]).
pub(crate) fn slave_from_table(t: &Table, ctx: &str, i: usize) -> Result<SlaveCfg> {
    let latency = t.get_or(ctx, "latency", 2)?;
    let kind = match t.get_or(ctx, "kind", "perfect".to_string())?.as_str() {
        "perfect" => SlaveKind::Perfect { latency },
        "simplex" => SlaveKind::Simplex { latency },
        "duplex" => SlaveKind::Duplex { banks: t.get_or(ctx, "banks", 2)?, latency },
        k => bail!("{ctx}.kind: unknown slave kind: {k}"),
    };
    Ok(SlaveCfg {
        name: t.get_or(ctx, "name", format!("s{i}"))?,
        kind,
        base: t.get_or(ctx, "base", (i as u64) * 0x1_0000)?,
        size: t.get_or(ctx, "size", 0x1_0000)?,
    })
}

/// A single-crossbar topology: the flat config surface of
/// `noc simulate`. Recursive multi-crossbar scenarios use the topology
/// grammar (`coordinator::topology::TopoCfg`) instead.
#[derive(Debug, Clone)]
pub struct SimCfg {
    pub cycles: u64,
    pub data_bits: usize,
    pub id_bits: usize,
    pub pipeline: bool,
    /// Engine choice and mode (`threads` / `epoch` / `full_scan` keys of
    /// `[sim]`), shared with every other stack via [`EngineOpts`].
    pub engine: EngineOpts,
    pub masters: Vec<MasterCfg>,
    pub slaves: Vec<SlaveCfg>,
}

impl SimCfg {
    pub fn from_doc(doc: &Doc) -> Result<Self> {
        let sim = doc.table("sim").context("missing [sim] section")?;
        let ctx = "sim";
        let cycles = sim.get_or(ctx, "cycles", 10_000)?;
        let data_bits = sim.get_or(ctx, "data_bits", 64)?;
        let id_bits = sim.get_or(ctx, "id_bits", 4)?;
        let pipeline = sim.get_or(ctx, "pipeline", false)?;
        let engine = EngineOpts::from_table(sim, ctx)?;

        let mut masters = Vec::new();
        for (i, t) in doc.array("master").iter().enumerate() {
            masters.push(master_from_table(t, &format!("master[{i}]"), i)?);
        }
        let mut slaves = Vec::new();
        for (i, t) in doc.array("slave").iter().enumerate() {
            slaves.push(slave_from_table(t, &format!("slave[{i}]"), i)?);
        }
        if masters.is_empty() || slaves.is_empty() {
            bail!("config needs at least one [[master]] and one [[slave]]");
        }
        Ok(SimCfg { cycles, data_bits, id_bits, pipeline, engine, masters, slaves })
    }

    pub fn from_str_toml(text: &str) -> Result<Self> {
        Self::from_doc(&parse(text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"
# quickstart topology
[sim]
cycles = 5000
data_bits = 64
id_bits = 4
pipeline = true

[[master]]
name = "gen0"
pattern = "uniform"
base = 0x0
span = 0x2_0000
reads = 0.7
total = 500

[[master]]
name = "gen1"
beats = 4

[[slave]]
name = "mem0"
kind = "duplex"
banks = 4
base = 0x0
size = 0x1_0000

[[slave]]
name = "mem1"
kind = "perfect"
latency = 10
base = 0x1_0000
size = 0x1_0000
"#;

    #[test]
    fn parses_example() {
        let cfg = SimCfg::from_str_toml(EXAMPLE).unwrap();
        assert_eq!(cfg.cycles, 5000);
        assert!(cfg.pipeline);
        assert_eq!(cfg.masters.len(), 2);
        assert_eq!(cfg.slaves.len(), 2);
        assert_eq!(cfg.masters[0].name, "gen0");
        assert_eq!(cfg.masters[0].span, 0x2_0000);
        assert!((cfg.masters[0].p_read - 0.7).abs() < 1e-9);
        assert_eq!(cfg.masters[1].beats, 4);
        assert_eq!(cfg.slaves[0].kind, SlaveKind::Duplex { banks: 4, latency: 2 });
        assert_eq!(cfg.slaves[1].kind, SlaveKind::Perfect { latency: 10 });
        assert_eq!(cfg.slaves[1].base, 0x1_0000);
    }

    #[test]
    fn value_types() {
        assert_eq!(parse_value("42").unwrap(), Value::Int(42));
        assert_eq!(parse_value("0x1F").unwrap(), Value::Int(31));
        assert_eq!(parse_value("2.5").unwrap(), Value::Float(2.5));
        assert_eq!(parse_value("true").unwrap(), Value::Bool(true));
        assert_eq!(parse_value("\"hi\"").unwrap(), Value::Str("hi".into()));
        assert!(parse_value("nope nope").is_err());
    }

    #[test]
    fn underscore_separators_in_all_numeric_literals() {
        assert_eq!(parse_value("2_000").unwrap(), Value::Int(2000));
        assert_eq!(parse_value("0x1_F").unwrap(), Value::Int(31));
        // Floats take underscores too (previously rejected).
        assert_eq!(parse_value("2_000.5").unwrap(), Value::Float(2000.5));
        assert_eq!(parse_value("1_0e2").unwrap(), Value::Float(1000.0));
        // Strings keep their underscores verbatim.
        assert_eq!(parse_value("\"a_b\"").unwrap(), Value::Str("a_b".into()));
    }

    #[test]
    fn hotspot_and_engine_keys_parse() {
        let text = EXAMPLE
            .replace(
                "pattern = \"uniform\"",
                "pattern = \"hotspot\"\np_hot = 0.8\nhot_span = 0x800",
            )
            .replace("[sim]", "[sim]\nfull_scan = true");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert!(cfg.engine.full_scan);
        assert!((cfg.masters[0].p_hot - 0.8).abs() < 1e-9);
        assert_eq!(cfg.masters[0].hot_span, Some(0x800));
        // Defaults on the second master.
        assert!((cfg.masters[1].p_hot - 0.5).abs() < 1e-9);
        assert_eq!(cfg.masters[1].hot_span, None);
    }

    #[test]
    fn threads_and_epoch_keys_parse_with_defaults() {
        let cfg = SimCfg::from_str_toml(EXAMPLE).unwrap();
        assert_eq!(
            cfg.engine.threads, None,
            "unset: library default is single-arena, CLI auto-picks"
        );
        assert_eq!(cfg.engine.epoch, 8);
        let text = EXAMPLE.replace("[sim]", "[sim]\nthreads = 4\nepoch = 16");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert_eq!(cfg.engine.threads, Some(4));
        assert_eq!(cfg.engine.epoch, 16);
        let text = EXAMPLE.replace("[sim]", "[sim]\nthreads = 0");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert_eq!(cfg.engine.threads, Some(0), "explicit 0 = single-arena");
    }

    #[test]
    fn rejects_zero_epoch() {
        let text = EXAMPLE.replace("[sim]", "[sim]\nepoch = 0");
        assert!(SimCfg::from_str_toml(&text).is_err());
    }

    #[test]
    fn epoch_policy_key_parses_and_rejects_bad_values() {
        use crate::sim::EpochPolicy;
        let cfg = SimCfg::from_str_toml(EXAMPLE).unwrap();
        assert_eq!(cfg.engine.policy, EpochPolicy::Fixed, "default is fixed");
        let text = EXAMPLE.replace("[sim]", "[sim]\nepoch_policy = \"adaptive\"");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert_eq!(cfg.engine.policy, EpochPolicy::Adaptive);
        let text = EXAMPLE.replace("[sim]", "[sim]\nepoch_policy = \"sometimes\"");
        let err = SimCfg::from_str_toml(&text).unwrap_err().to_string();
        assert!(err.contains("sim.epoch_policy"), "{err}");
    }

    #[test]
    fn rejects_out_of_range_thread_count() {
        let text = EXAMPLE.replace("[sim]", "[sim]\nthreads = 40000");
        let err = SimCfg::from_str_toml(&text).unwrap_err().to_string();
        assert!(err.contains("1024"), "{err}");
    }

    #[test]
    fn rejects_out_of_range_p_hot() {
        let text = EXAMPLE.replace("pattern = \"uniform\"", "pattern = \"hotspot\"\np_hot = 1.5");
        assert!(SimCfg::from_str_toml(&text).is_err());
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = parse("# top\n[sim]\n# inner\ncycles = 1 # trailing\n").unwrap();
        assert_eq!(doc.table("sim").unwrap()["cycles"], Value::Int(1));
    }

    #[test]
    fn rejects_key_outside_section() {
        assert!(parse("cycles = 1").is_err());
    }

    #[test]
    fn missing_sections_fail_typed_parse() {
        assert!(SimCfg::from_str_toml("[sim]\ncycles = 1").is_err());
    }

    #[test]
    fn scoped_arrays_attach_to_their_parent() {
        let text = r#"
[[template]]
name = "a"
[[template.master]]
name = "a0"
[[template.master]]
name = "a1"
[[template]]
name = "b"
[[template.master]]
name = "b0"
[[template.child]]
template = "a"
"#;
        let doc = parse(text).unwrap();
        assert_eq!(doc.array("template").len(), 2);
        let a = doc.scoped("template", 0, "master");
        assert_eq!(a.len(), 2);
        assert_eq!(a[1]["name"], Value::Str("a1".into()));
        let b = doc.scoped("template", 1, "master");
        assert_eq!(b.len(), 1);
        assert_eq!(b[0]["name"], Value::Str("b0".into()));
        assert_eq!(doc.scoped("template", 0, "child").len(), 0);
        assert_eq!(doc.scoped("template", 1, "child").len(), 1);
        assert_eq!(doc.scoped("template", 1, "slave").len(), 0, "absent scoped array is empty");
    }

    #[test]
    fn orphan_scoped_array_is_an_error() {
        let e = parse("[[template.master]]\nname = \"x\"\n").unwrap_err().to_string();
        assert!(e.contains("before any [[template]]"), "got: {e}");
    }

    #[test]
    fn typed_accessors_carry_field_paths() {
        let text = EXAMPLE.replace("beats = 4", "beats = \"lots\"");
        let e = SimCfg::from_str_toml(&text).unwrap_err().to_string();
        assert!(e.contains("master[1].beats"), "field path in error, got: {e}");
        let t = Table::new();
        let e = t.require::<u64>("template[cluster].master[2]", "beats").unwrap_err().to_string();
        assert_eq!(e, "template[cluster].master[2].beats: missing required key");
    }
}
