//! Coordinator: configuration system, topology builder, and reporting —
//! the launcher surface of the platform (`noc simulate --config ...`).
//!
//! Built systems run on the activity-tracked event engine; the
//! `full_scan` config key (or `--full-scan`) keeps the every-cycle scan
//! as an A/B oracle whose results must be bit-identical
//! ([`determinism_fingerprint`]).

pub mod builder;
pub mod config;
pub mod report;
pub mod topology;

pub use builder::{SlaveTap, System};
pub use config::{parse, Doc, FromValue, SimCfg, Table, Value};
pub use report::{determinism_fingerprint, run_report, run_summary, Json};
pub use topology::TopoCfg;
