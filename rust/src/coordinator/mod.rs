//! Coordinator: configuration system, topology builder, and reporting —
//! the launcher surface of the platform (`noc simulate --config ...`).

pub mod builder;
pub mod config;
pub mod report;

pub use builder::System;
pub use config::{parse, Doc, SimCfg, Value};
pub use report::{run_report, run_summary, Json};
