//! Topology builder: instantiate a configured single-crossbar system —
//! traffic generators → (optionally pipelined) crossbar → endpoints —
//! with protocol monitors on every master port.
//!
//! The built [`System`] runs on the activity-tracked event engine
//! (`sim::engine`): every generator, monitor, endpoint, and crossbar
//! *part* (per-port demux/mux/pipeline stage, see `Xbar::into_parts`)
//! registers individually in the engine arena with bound wake edges, so
//! idle parts of the topology are skipped entirely. `SimCfg::full_scan`
//! keeps the pre-engine every-cycle mode as an A/B oracle: both modes
//! must produce bit-identical generator stats and monitor violation
//! streams (`rust/tests/coordinator_engine.rs`), and
//! `benches/coordinator_engine.rs` records the cycles/sec of each.
//!
//! With `SimCfg::engine.threads >= 1` (`noc simulate --threads N`) the
//! system builds on the sharded engine instead: each master island
//! (generator plus monitor) gets its own shard, the crossbar and
//! endpoints live in shard 0, and the monitor→crossbar bundles are cut
//! with `protocol::exchange` relays swapped at epoch barriers. The shard
//! structure is independent of the thread count, so
//! `coordinator::determinism_fingerprint` is bit-identical for every
//! `N >= 1` in both engine modes.
//!
//! Recursive multi-crossbar scenarios (`coordinator::topology`) reuse
//! this module's pieces — [`master_pattern`], [`gen_cfg`],
//! [`SlaveTap::new`], [`System::from_parts`] — so a degenerate
//! single-template grammar config builds the *same* system, name for
//! name and seed for seed.

use std::cell::RefCell;
use std::rc::Rc;

use crate::bail;
use crate::errors::Result;

use crate::coordinator::config::{MasterCfg, SimCfg, SlaveKind};
use crate::noc::addr_decode::{AddrMap, AddrRule, DefaultPort};
use crate::noc::mem_duplex::{BankArray, MemDuplex};
use crate::noc::mem_simplex::{ArbPolicy, MemSimplex};
use crate::noc::sram::Sram;
use crate::noc::xbar::{xbar_master_id_bits, Xbar, XbarCfg};
use crate::protocol::channel::Tap;
use crate::protocol::exchange::cut_slave_export;
use crate::protocol::{bundle, BundleCfg, MasterEnd, Monitor, RBeat, WBeat};
use crate::sim::{shared, Arena, Cycle};
use crate::traffic::gen::{AddrPattern, RwGen, RwGenCfg};
use crate::traffic::perfect_slave::PerfectSlave;

/// Default hotspot window size, clamped to the master's span at build.
const DEFAULT_HOT_SPAN: u64 = 0x1000;

/// Passive bandwidth tap on one endpoint's crossbar master port (data
/// channels in both directions), so reports and tests can attribute
/// traffic to slaves after the port ends moved into their modules.
pub struct SlaveTap {
    pub name: String,
    w: Tap<WBeat>,
    r: Tap<RBeat>,
    beat_bytes: u64,
}

impl SlaveTap {
    /// Tap the data channels of `m` (an endpoint's crossbar master port)
    /// before the end moves into its module.
    pub(crate) fn new(name: String, m: &MasterEnd) -> SlaveTap {
        SlaveTap { name, w: m.w.tap(), r: m.r.tap(), beat_bytes: m.cfg.beat_bytes() as u64 }
    }

    /// Data beats that crossed this slave's port (W in + R out).
    pub fn data_beats(&self) -> u64 {
        self.w.stats().handshakes + self.r.stats().handshakes
    }

    /// Same, in bytes.
    pub fn data_bytes(&self) -> u64 {
        self.data_beats() * self.beat_bytes
    }
}

/// A built system ready to run.
pub struct System {
    pub name: String,
    arena: Arena,
    pub gens: Vec<Rc<RefCell<RwGen>>>,
    pub monitors: Vec<Rc<RefCell<Monitor>>>,
    /// One tap per configured slave, in `SimCfg::slaves` order.
    pub slave_taps: Vec<SlaveTap>,
    pub cycles: Cycle,
}

/// Construct the generator address pattern for one master. `port_cfg` is
/// the bundle at the generator's master port (the sequential stride and
/// hotspot window derive from it and the master config).
pub(crate) fn master_pattern(mc: &MasterCfg, port_cfg: &BundleCfg) -> Result<AddrPattern> {
    Ok(match mc.pattern.as_str() {
        "uniform" => AddrPattern::Uniform { base: mc.base, span: mc.span },
        "sequential" => {
            // One transaction covers beats * beat_bytes; stride by whole
            // bursts so consecutive transactions tile the range without
            // overlapping at any data width or burst length.
            let stride = (mc.beats.max(1) * port_cfg.beat_bytes()) as u64;
            AddrPattern::Sequential { base: mc.base, stride }
        }
        "hotspot" => {
            // The hot window must stay inside the master's span: a window
            // larger than the span would emit addresses outside every
            // decode rule and land the traffic on the error path.
            let hot_span = mc.hot_span.unwrap_or(DEFAULT_HOT_SPAN).min(mc.span).max(1);
            AddrPattern::Hotspot {
                base: mc.base,
                span: mc.span,
                hot_base: mc.base,
                hot_span,
                p_hot: mc.p_hot,
            }
        }
        p => bail!("unknown pattern: {p}"),
    })
}

/// The full generator config for one master. `seed_idx` is the master's
/// global walk index — the seed schedule (`0xC0FFEE + idx`) is part of
/// the determinism fingerprint contract, so the flat builder and the
/// topology grammar derive it from the same walk order.
pub(crate) fn gen_cfg(mc: &MasterCfg, port_cfg: &BundleCfg, seed_idx: u64) -> Result<RwGenCfg> {
    Ok(RwGenCfg {
        pattern: master_pattern(mc, port_cfg)?,
        p_read: mc.p_read,
        beats: mc.beats,
        n_ids: mc.n_ids,
        max_outstanding: mc.max_outstanding,
        total: mc.total,
        p_issue: 1.0,
        verify: false, // endpoints may be real memories (zeroed)
        seed: 0xC0FFEE + seed_idx,
    })
}

/// Build the crossbar address rules from the slave configs. Validates
/// what `AddrMap` would otherwise only assert on (or silently accept):
/// `base + size` must not wrap the address space, and ranges must be
/// pairwise disjoint — an overlap would shadow-route everything behind
/// the first matching rule.
fn slave_rules(cfg: &SimCfg) -> Result<Vec<AddrRule>> {
    let mut rules: Vec<AddrRule> = Vec::with_capacity(cfg.slaves.len());
    for (i, sc) in cfg.slaves.iter().enumerate() {
        if sc.size == 0 {
            bail!("slave {}: size must be nonzero", sc.name);
        }
        let end = match sc.base.checked_add(sc.size) {
            Some(e) => e,
            None => bail!(
                "slave {}: base {:#x} + size {:#x} wraps the 64-bit address space",
                sc.name,
                sc.base,
                sc.size
            ),
        };
        for (j, r) in rules.iter().enumerate() {
            if sc.base < r.end && r.start < end {
                bail!(
                    "slaves {} [{:#x}, {:#x}) and {} [{:#x}, {:#x}) overlap",
                    cfg.slaves[j].name,
                    r.start,
                    r.end,
                    sc.name,
                    sc.base,
                    end
                );
            }
        }
        rules.push(AddrRule::new(sc.base, end, i));
    }
    Ok(rules)
}

impl System {
    /// Wrap an already-populated arena (the topology grammar's entry
    /// point — `coordinator::topology` registers the component tree
    /// itself, then hands over the run-time handles).
    pub(crate) fn from_parts(
        name: String,
        arena: Arena,
        gens: Vec<Rc<RefCell<RwGen>>>,
        monitors: Vec<Rc<RefCell<Monitor>>>,
        slave_taps: Vec<SlaveTap>,
    ) -> Self {
        System { name, arena, gens, monitors, slave_taps, cycles: 0 }
    }

    pub fn build(cfg: &SimCfg) -> Result<Self> {
        let s_cfg = BundleCfg::new(cfg.data_bits, cfg.id_bits);
        let m_cfg = BundleCfg::new(
            cfg.data_bits,
            xbar_master_id_bits(cfg.id_bits, cfg.masters.len()),
        );
        // `threads` unset = the single-arena engine (the CLI resolves
        // `None` to the host core count before building; see main.rs).
        // `Arena::new` applies threads/epoch/policy/full_scan itself;
        // `epoch` stays local for the cut-relay capacities below.
        let epoch = cfg.engine.epoch.max(1);
        let mut arena = Arena::new(&cfg.engine, cfg.masters.len() + 1);
        let mut gens = Vec::new();
        let mut monitors = Vec::new();

        // Masters -> monitors -> crossbar slave ports. In sharded mode
        // each master island lives in shard i + 1 and its output bundle
        // is cut toward the crossbar in shard 0.
        let mut xbar_slaves = Vec::new();
        for (i, mc) in cfg.masters.iter().enumerate() {
            let (gen_m, gen_s) = bundle(&format!("{}.port", mc.name), s_cfg);
            let (mon_m, mon_s) = bundle(&format!("{}.mon", mc.name), s_cfg);
            let (g, g_adapter) =
                shared(RwGen::new(mc.name.clone(), gen_m, gen_cfg(mc, &s_cfg, i as u64)?));
            gens.push(g);
            let (mon, mon_adapter) =
                shared(Monitor::new(format!("{}.monitor", mc.name), gen_s, mon_m));
            monitors.push(mon);
            match &mut arena {
                Arena::Single { engine, domain } => {
                    engine.add(*domain, g_adapter);
                    engine.add(*domain, mon_adapter);
                    xbar_slaves.push(mon_s);
                }
                Arena::Sharded { eng } => {
                    let (cut, far_s) =
                        cut_slave_export(&format!("cut.{}", mc.name), s_cfg, mon_s, epoch);
                    // SAFETY: the island's only outbound bundle (monitor
                    // -> crossbar) was cut just above; shard i+1 holds
                    // the generator, monitor, and sender relay, shard 0
                    // the receiver half — they share only the exchange
                    // queues (whose wakes `register` wires up, letting
                    // the relays sleep), and the `gens`/`monitors`
                    // handles are read between runs only.
                    unsafe {
                        let sh = eng.shard(i + 1);
                        sh.add(g_adapter);
                        sh.add(mon_adapter);
                        cut.register(eng, i + 1, 0);
                    }
                    xbar_slaves.push(far_s);
                }
            }
        }

        // Crossbar master ports -> endpoints (address map validated first).
        let rules = slave_rules(cfg)?;
        let map = AddrMap::new(rules, DefaultPort::Error);
        let mut xbar_masters = Vec::new();
        let mut slave_taps = Vec::new();
        for sc in &cfg.slaves {
            let (m, s) = bundle(&format!("{}.port", sc.name), m_cfg);
            slave_taps.push(SlaveTap::new(sc.name.clone(), &m));
            xbar_masters.push(m);
            match &sc.kind {
                SlaveKind::Perfect { latency } => {
                    arena.add_infra(Box::new(PerfectSlave::new(sc.name.clone(), s, *latency)));
                }
                SlaveKind::Simplex { latency } => {
                    let sram = Sram::new(sc.base, sc.size as usize, *latency);
                    arena.add_infra(Box::new(MemSimplex::new(
                        sc.name.clone(),
                        s,
                        sram,
                        ArbPolicy::RoundRobin,
                    )));
                }
                SlaveKind::Duplex { banks, latency } => {
                    let arr = BankArray::new(
                        sc.base,
                        (sc.size as usize).div_ceil(*banks),
                        *banks,
                        m_cfg.beat_bytes(),
                        *latency,
                    );
                    arena.add_infra(Box::new(MemDuplex::new(sc.name.clone(), s, arr)));
                }
            }
        }

        let xbar = Xbar::new(
            "xbar",
            xbar_slaves,
            xbar_masters,
            XbarCfg {
                slave_cfg: s_cfg,
                maps: vec![map; cfg.masters.len()],
                max_txns_per_id: 8,
                pipeline: cfg.pipeline,
            },
        );
        // Finer wake granularity: each demux/mux/pipeline/error-slave
        // registers individually, so a beat wakes only the port it
        // touches instead of the whole crossbar.
        for part in xbar.into_parts() {
            arena.add_infra(part);
        }

        Ok(System { name: "system".into(), arena, gens, monitors, slave_taps, cycles: 0 })
    }

    /// Advance one cycle on the engine calendar (only awake components
    /// tick; in full-scan mode, all of them).
    pub fn step(&mut self) {
        self.run_for(1);
    }

    pub fn all_done(&self) -> bool {
        self.gens.iter().all(|g| {
            let g = g.borrow();
            g.done() && g.idle()
        })
    }

    /// Run for up to `budget` cycles or until all generators finish. In
    /// sharded mode the completion check (which reads generator state
    /// owned by worker threads mid-run) happens only at epoch
    /// boundaries, so the stopping cycle is identical for every thread
    /// count (single-arena mode degrades to per-cycle checks).
    pub fn run(&mut self, budget: Cycle) -> bool {
        let mut left = budget;
        while left > 0 {
            let step = self.arena.to_next_exchange().min(left);
            self.run_for(step);
            left -= step;
            if self.all_done() {
                return true;
            }
        }
        self.all_done()
    }

    /// Run for exactly `cycles` cycles, with no early exit — benches use
    /// this so event and full-scan modes simulate identical windows.
    pub fn run_for(&mut self, cycles: Cycle) {
        self.arena.advance(cycles);
        self.cycles += cycles;
        debug_assert_eq!(self.arena.cycles(), self.cycles);
    }

    /// Assert protocol compliance across all monitors.
    pub fn check_protocol(&self) -> Vec<crate::protocol::Violation> {
        self.monitors
            .iter()
            .flat_map(|m| m.borrow().violations().to_vec())
            .collect()
    }

    /// Whether this system runs in the full-scan A/B mode.
    pub fn full_scan(&self) -> bool {
        !self.arena.sleep_enabled()
    }

    /// Worker threads driving the simulation (0 = single-arena engine).
    pub fn threads(&self) -> usize {
        self.arena.threads()
    }

    /// The engine mode as a report label.
    pub fn mode_str(&self) -> &'static str {
        if self.full_scan() {
            "full_scan"
        } else {
            "event"
        }
    }

    /// Components registered in the engine arena(s).
    pub fn component_count(&self) -> usize {
        self.arena.component_count()
    }

    /// Currently-awake components (observability; in full-scan mode every
    /// component stays awake — in sharded event mode even the cut relays
    /// sleep between exchanges, so a drained system reaches zero).
    pub fn awake_components(&self) -> usize {
        self.arena.awake_components()
    }

    /// The sharded engine's accumulated cycle profile — per-shard run
    /// time and awake-integral, per-worker stall/exchange split, and the
    /// run/sprint/exchange counters (`None` in single-arena mode).
    pub fn shard_profile(&self) -> Option<crate::sim::ShardProfileReport> {
        self.arena.shard_profile()
    }

    /// Whether the telemetry layer is attached (`--telemetry`/`--trace`).
    pub fn telemetry_enabled(&self) -> bool {
        self.arena.telemetry_enabled()
    }

    /// Drain every trace ring into one export-sorted event list plus the
    /// total dropped-event count (empty when telemetry is off).
    pub fn take_trace_events(&mut self) -> (Vec<crate::telemetry::TraceEvent>, u64) {
        self.arena.take_trace_events()
    }

    /// Per-component energy integral over the run so far. Configured
    /// topologies have no floorplan, so every component prices at the
    /// default infrastructure weight — useful for *relative* comparisons
    /// between runs, not absolute silicon numbers. Empty (zero total)
    /// when telemetry is off.
    pub fn energy_report(&self) -> crate::telemetry::EnergyReport {
        let mut r = crate::telemetry::EnergyReport::new(self.cycles);
        for (name, active) in self.arena.meter_rows() {
            r.add_component(&name, active);
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SimCfg;

    const CFG: &str = r#"
[sim]
cycles = 20000
data_bits = 64
id_bits = 4

[[master]]
name = "gen0"
base = 0x0
span = 0x2_0000
reads = 0.6
total = 200

[[master]]
name = "gen1"
base = 0x0
span = 0x2_0000
beats = 4
total = 100

[[slave]]
name = "mem0"
kind = "duplex"
banks = 4
base = 0x0
size = 0x1_0000

[[slave]]
name = "mem1"
kind = "simplex"
base = 0x1_0000
size = 0x1_0000
"#;

    #[test]
    fn builds_and_completes_with_clean_protocol() {
        let cfg = SimCfg::from_str_toml(CFG).unwrap();
        let mut sys = System::build(&cfg).unwrap();
        assert!(!sys.full_scan());
        let done = sys.run(cfg.cycles);
        assert!(done, "all traffic must complete");
        let violations = sys.check_protocol();
        assert!(violations.is_empty(), "{violations:#?}");
        let total: u64 = sys.gens.iter().map(|g| g.borrow().stats.completed).sum();
        assert_eq!(total, 300);
    }

    #[test]
    fn sharded_system_completes_with_clean_protocol() {
        let text = CFG.replace("[sim]", "[sim]\nthreads = 2\nepoch = 4");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        let mut sys = System::build(&cfg).unwrap();
        assert_eq!(sys.threads(), 2);
        let done = sys.run(cfg.cycles);
        assert!(done, "sharded traffic must complete");
        assert!(sys.check_protocol().is_empty());
        let total: u64 = sys.gens.iter().map(|g| g.borrow().stats.completed).sum();
        assert_eq!(total, 300);
    }

    #[test]
    fn pipelined_variant_also_clean() {
        let text = CFG.replace("id_bits = 4", "id_bits = 4\npipeline = true");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        let mut sys = System::build(&cfg).unwrap();
        assert!(sys.run(cfg.cycles));
        assert!(sys.check_protocol().is_empty());
    }

    #[test]
    fn full_scan_mode_keeps_everything_awake() {
        let text = CFG.replace("[sim]", "[sim]\nfull_scan = true");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        let mut sys = System::build(&cfg).unwrap();
        assert!(sys.full_scan());
        assert!(sys.run(cfg.cycles));
        assert_eq!(sys.awake_components(), sys.component_count());
    }

    #[test]
    fn event_mode_sleeps_when_drained() {
        let cfg = SimCfg::from_str_toml(CFG).unwrap();
        let mut sys = System::build(&cfg).unwrap();
        assert!(sys.run(cfg.cycles));
        // All traffic retired: the whole topology must go to sleep.
        sys.run_for(100);
        let awake = sys.awake_components();
        let total = sys.component_count();
        assert!(awake * 10 <= total, "drained system should sleep: {awake}/{total} awake");
    }

    #[test]
    fn rejects_unknown_pattern() {
        let text = CFG.replace("name = \"gen0\"", "name = \"gen0\"\npattern = \"zigzag\"");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert!(System::build(&cfg).is_err());
    }

    #[test]
    fn rejects_overlapping_slave_ranges() {
        // mem1 moved onto mem0's range: must be a config error, not a
        // silent shadow route.
        let text =
            CFG.replace("base = 0x1_0000\nsize = 0x1_0000", "base = 0x8000\nsize = 0x1_0000");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        let err = System::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");
    }

    #[test]
    fn rejects_wrapping_slave_range() {
        // A base this high is not expressible through the i64-backed TOML
        // layer, so patch the typed config directly.
        use crate::coordinator::config::{SlaveCfg, SlaveKind};
        let mut cfg = SimCfg::from_str_toml(CFG).unwrap();
        cfg.slaves[1] = SlaveCfg {
            name: "high".into(),
            kind: SlaveKind::Perfect { latency: 1 },
            base: u64::MAX - 0xFFF,
            size: 0x2000,
        };
        let err = System::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("wraps"), "{err}");
    }

    #[test]
    fn rejects_empty_slave_range() {
        let text = CFG.replace("base = 0x1_0000\nsize = 0x1_0000", "base = 0x1_0000\nsize = 0x0");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        let err = System::build(&cfg).unwrap_err().to_string();
        assert!(err.contains("nonzero"), "{err}");
    }

    #[test]
    fn hotspot_window_clamps_to_span() {
        let port = BundleCfg::new(64, 4);
        let mc = MasterCfg {
            name: "m".into(),
            pattern: "hotspot".into(),
            base: 0x1000,
            span: 0x200, // smaller than the 0x1000 default hot window
            p_read: 1.0,
            beats: 1,
            total: Some(1),
            max_outstanding: 1,
            n_ids: 1,
            p_hot: 0.9,
            hot_span: None,
        };
        match master_pattern(&mc, &port).unwrap() {
            AddrPattern::Hotspot { hot_base, hot_span, p_hot, .. } => {
                assert_eq!(hot_base, 0x1000);
                assert_eq!(hot_span, 0x200, "hot window clamped to the span");
                assert!((p_hot - 0.9).abs() < 1e-9);
            }
            p => panic!("expected hotspot, got {p:?}"),
        }
        // An explicit window is clamped too.
        let mc = MasterCfg { hot_span: Some(0x10_0000), ..mc };
        match master_pattern(&mc, &port).unwrap() {
            AddrPattern::Hotspot { hot_span, .. } => assert_eq!(hot_span, 0x200),
            p => panic!("expected hotspot, got {p:?}"),
        }
    }

    #[test]
    fn sequential_stride_follows_burst_footprint() {
        let mc = MasterCfg {
            name: "m".into(),
            pattern: "sequential".into(),
            base: 0,
            span: 0x1_0000,
            p_read: 1.0,
            beats: 4,
            total: Some(1),
            max_outstanding: 1,
            n_ids: 1,
            p_hot: 0.5,
            hot_span: None,
        };
        // 512-bit data: 64 B/beat * 4 beats = 256 B per burst. The old
        // hardcoded 64 B stride made consecutive bursts overlap here.
        match master_pattern(&mc, &BundleCfg::new(512, 4)).unwrap() {
            AddrPattern::Sequential { stride, .. } => assert_eq!(stride, 256),
            p => panic!("expected sequential, got {p:?}"),
        }
        // 64-bit data, 4 beats: 32 B strides tile the range gaplessly.
        match master_pattern(&mc, &BundleCfg::new(64, 4)).unwrap() {
            AddrPattern::Sequential { stride, .. } => assert_eq!(stride, 32),
            p => panic!("expected sequential, got {p:?}"),
        }
        // Single-beat narrow master: one beat per burst, 8 B stride.
        let mc = MasterCfg { beats: 1, ..mc };
        match master_pattern(&mc, &BundleCfg::new(64, 4)).unwrap() {
            AddrPattern::Sequential { stride, .. } => assert_eq!(stride, 8),
            p => panic!("expected sequential, got {p:?}"),
        }
    }
}
