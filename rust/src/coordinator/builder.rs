//! Topology builder: instantiate a configured single-crossbar system —
//! traffic generators → (optionally pipelined) crossbar → endpoints —
//! with protocol monitors on every master port.

use std::cell::RefCell;
use std::rc::Rc;

use crate::bail;
use crate::errors::Result;

use crate::coordinator::config::{SimCfg, SlaveKind};
use crate::noc::addr_decode::{AddrMap, AddrRule, DefaultPort};
use crate::noc::mem_duplex::{BankArray, MemDuplex};
use crate::noc::mem_simplex::{ArbPolicy, MemSimplex};
use crate::noc::sram::Sram;
use crate::noc::xbar::{xbar_master_id_bits, Xbar, XbarCfg};
use crate::protocol::{bundle, BundleCfg, Monitor};
use crate::sim::{shared, Component, Cycle};
use crate::traffic::gen::{AddrPattern, RwGen, RwGenCfg};
use crate::traffic::perfect_slave::PerfectSlave;

/// A built system ready to run.
pub struct System {
    pub name: String,
    components: Vec<Box<dyn Component>>,
    pub gens: Vec<Rc<RefCell<RwGen>>>,
    pub monitors: Vec<Rc<RefCell<Monitor>>>,
    pub cycles: Cycle,
}

impl System {
    pub fn build(cfg: &SimCfg) -> Result<Self> {
        let s_cfg = BundleCfg::new(cfg.data_bits, cfg.id_bits);
        let m_cfg = BundleCfg::new(
            cfg.data_bits,
            xbar_master_id_bits(cfg.id_bits, cfg.masters.len()),
        );
        let mut components: Vec<Box<dyn Component>> = Vec::new();
        let mut gens = Vec::new();
        let mut monitors = Vec::new();

        // Masters -> monitors -> crossbar slave ports.
        let mut xbar_slaves = Vec::new();
        for (i, mc) in cfg.masters.iter().enumerate() {
            let (gen_m, gen_s) = bundle(&format!("{}.port", mc.name), s_cfg);
            let (mon_m, mon_s) = bundle(&format!("{}.mon", mc.name), s_cfg);
            let pattern = match mc.pattern.as_str() {
                "uniform" => AddrPattern::Uniform { base: mc.base, span: mc.span },
                "sequential" => AddrPattern::Sequential { base: mc.base, stride: 64 },
                "hotspot" => AddrPattern::Hotspot {
                    base: mc.base,
                    span: mc.span,
                    hot_base: mc.base,
                    hot_span: 0x1000,
                    p_hot: 0.5,
                },
                p => bail!("unknown pattern: {p}"),
            };
            let gen_cfg = RwGenCfg {
                pattern,
                p_read: mc.p_read,
                beats: mc.beats,
                n_ids: mc.n_ids,
                max_outstanding: mc.max_outstanding,
                total: mc.total,
                p_issue: 1.0,
                verify: false, // endpoints may be real memories (zeroed)
                seed: 0xC0FFEE + i as u64,
            };
            let (g, g_adapter) = shared(RwGen::new(mc.name.clone(), gen_m, gen_cfg));
            gens.push(g);
            components.push(Box::new(g_adapter));
            let (mon, mon_adapter) =
                shared(Monitor::new(format!("{}.monitor", mc.name), gen_s, mon_m));
            monitors.push(mon);
            components.push(Box::new(mon_adapter));
            xbar_slaves.push(mon_s);
        }

        // Crossbar master ports -> endpoints.
        let rules: Vec<AddrRule> = cfg
            .slaves
            .iter()
            .enumerate()
            .map(|(i, sc)| AddrRule::new(sc.base, sc.base + sc.size, i))
            .collect();
        let map = AddrMap::new(rules, DefaultPort::Error);
        let mut xbar_masters = Vec::new();
        for sc in &cfg.slaves {
            let (m, s) = bundle(&format!("{}.port", sc.name), m_cfg);
            xbar_masters.push(m);
            match &sc.kind {
                SlaveKind::Perfect { latency } => {
                    components.push(Box::new(PerfectSlave::new(sc.name.clone(), s, *latency)));
                }
                SlaveKind::Simplex { latency } => {
                    let sram = Sram::new(sc.base, sc.size as usize, *latency);
                    components.push(Box::new(MemSimplex::new(
                        sc.name.clone(),
                        s,
                        sram,
                        ArbPolicy::RoundRobin,
                    )));
                }
                SlaveKind::Duplex { banks, latency } => {
                    let arr = BankArray::new(
                        sc.base,
                        (sc.size as usize).div_ceil(*banks),
                        *banks,
                        m_cfg.beat_bytes(),
                        *latency,
                    );
                    components.push(Box::new(MemDuplex::new(sc.name.clone(), s, arr)));
                }
            }
        }

        let xbar = Xbar::new(
            "xbar",
            xbar_slaves,
            xbar_masters,
            XbarCfg {
                slave_cfg: s_cfg,
                maps: vec![map; cfg.masters.len()],
                max_txns_per_id: 8,
                pipeline: cfg.pipeline,
            },
        );
        components.push(Box::new(xbar));

        Ok(System { name: "system".into(), components, gens, monitors, cycles: 0 })
    }

    pub fn step(&mut self) {
        self.cycles += 1;
        let cy = self.cycles;
        for c in &mut self.components {
            c.tick(cy);
        }
    }

    pub fn all_done(&self) -> bool {
        self.gens.iter().all(|g| {
            let g = g.borrow();
            g.done() && g.idle()
        })
    }

    /// Run for up to `budget` cycles or until all generators finish.
    pub fn run(&mut self, budget: Cycle) -> bool {
        for _ in 0..budget {
            self.step();
            if self.all_done() {
                return true;
            }
        }
        self.all_done()
    }

    /// Assert protocol compliance across all monitors.
    pub fn check_protocol(&self) -> Vec<crate::protocol::Violation> {
        self.monitors
            .iter()
            .flat_map(|m| m.borrow().violations().to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::SimCfg;

    const CFG: &str = r#"
[sim]
cycles = 20000
data_bits = 64
id_bits = 4

[[master]]
name = "gen0"
base = 0x0
span = 0x2_0000
reads = 0.6
total = 200

[[master]]
name = "gen1"
base = 0x0
span = 0x2_0000
beats = 4
total = 100

[[slave]]
name = "mem0"
kind = "duplex"
banks = 4
base = 0x0
size = 0x1_0000

[[slave]]
name = "mem1"
kind = "simplex"
base = 0x1_0000
size = 0x1_0000
"#;

    #[test]
    fn builds_and_completes_with_clean_protocol() {
        let cfg = SimCfg::from_str_toml(CFG).unwrap();
        let mut sys = System::build(&cfg).unwrap();
        let done = sys.run(cfg.cycles);
        assert!(done, "all traffic must complete");
        let violations = sys.check_protocol();
        assert!(violations.is_empty(), "{violations:#?}");
        let total: u64 = sys.gens.iter().map(|g| g.borrow().stats.completed).sum();
        assert_eq!(total, 300);
    }

    #[test]
    fn pipelined_variant_also_clean() {
        let text = CFG.replace("id_bits = 4", "id_bits = 4\npipeline = true");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        let mut sys = System::build(&cfg).unwrap();
        assert!(sys.run(cfg.cycles));
        assert!(sys.check_protocol().is_empty());
    }

    #[test]
    fn rejects_unknown_pattern() {
        let text = CFG.replace("name = \"gen0\"", "name = \"gen0\"\npattern = \"zigzag\"");
        let cfg = SimCfg::from_str_toml(&text).unwrap();
        assert!(System::build(&cfg).is_err());
    }
}
