//! Result reporting: human-readable tables and a minimal JSON emitter
//! (serde is unavailable offline).

use crate::coordinator::builder::System;

/// Minimal JSON value builder for reports.
#[derive(Debug, Clone)]
pub enum Json {
    Num(f64),
    Str(String),
    Bool(bool),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        match self {
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Json::Bool(b) => b.to_string(),
            Json::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Json::Arr(a) => {
                format!("[{}]", a.iter().map(|v| v.render()).collect::<Vec<_>>().join(","))
            }
            Json::Obj(o) => format!(
                "{{{}}}",
                o.iter()
                    .map(|(k, v)| format!("\"{k}\":{}", v.render()))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

/// Per-generator summary of a run.
pub fn run_report(sys: &System) -> Json {
    let mut gens = Vec::new();
    for g in &sys.gens {
        let g = g.borrow();
        let s = &g.stats;
        gens.push(Json::Obj(vec![
            ("name".into(), Json::Str(g.name().to_string())),
            ("issued".into(), Json::Num(s.issued as f64)),
            ("completed".into(), Json::Num(s.completed as f64)),
            ("bytes".into(), Json::Num(s.bytes as f64)),
            ("read_lat_mean".into(), Json::Num(s.read_latency.mean())),
            ("read_lat_p99".into(), Json::Num(s.read_latency.percentile(99.0) as f64)),
            ("write_lat_mean".into(), Json::Num(s.write_latency.mean())),
            ("data_errors".into(), Json::Num(s.data_errors as f64)),
        ]));
    }
    let violations = sys.check_protocol();
    Json::Obj(vec![
        ("cycles".into(), Json::Num(sys.cycles as f64)),
        ("generators".into(), Json::Arr(gens)),
        ("protocol_violations".into(), Json::Num(violations.len() as f64)),
    ])
}

/// Human-readable run summary.
pub fn run_summary(sys: &System) -> String {
    let mut out = format!("run: {} cycles\n", sys.cycles);
    out.push_str(&format!(
        "{:<12}{:>8}{:>10}{:>12}{:>14}{:>14}{:>8}\n",
        "generator", "issued", "done", "bytes", "rd lat mean", "wr lat mean", "errs"
    ));
    for g in &sys.gens {
        let g = g.borrow();
        let s = &g.stats;
        out.push_str(&format!(
            "{:<12}{:>8}{:>10}{:>12}{:>14.1}{:>14.1}{:>8}\n",
            g.name(),
            s.issued,
            s.completed,
            s.bytes,
            s.read_latency.mean(),
            s.write_latency.mean(),
            s.data_errors
        ));
    }
    let v = sys.check_protocol();
    out.push_str(&format!("protocol violations: {}\n", v.len()));
    out
}

// The generator needs a name accessor for reports.
impl crate::traffic::gen::RwGen {
    pub fn name(&self) -> &str {
        crate::sim::Component::name(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_renders() {
        let j = Json::Obj(vec![
            ("a".into(), Json::Num(1.0)),
            ("b".into(), Json::Str("x\"y".into())),
            ("c".into(), Json::Arr(vec![Json::Bool(true), Json::Num(2.5)])),
        ]);
        assert_eq!(j.render(), r#"{"a":1,"b":"x\"y","c":[true,2.5]}"#);
    }

    #[test]
    fn report_over_built_system() {
        let cfg = crate::coordinator::config::SimCfg::from_str_toml(
            r#"
[sim]
cycles = 10000
[[master]]
total = 50
span = 0x1000
[[slave]]
kind = "perfect"
base = 0x0
size = 0x1000
"#,
        )
        .unwrap();
        let mut sys = System::build(&cfg).unwrap();
        sys.run(cfg.cycles);
        let j = run_report(&sys).render();
        assert!(j.contains("\"completed\":50"), "{j}");
        let s = run_summary(&sys);
        assert!(s.contains("protocol violations: 0"));
    }
}
