//! Result reporting: human-readable tables and a minimal JSON emitter
//! (serde is unavailable offline), plus the determinism fingerprint the
//! event-vs-full-scan A/B oracle compares.

use crate::coordinator::builder::{SlaveTap, System};

/// Minimal JSON value builder for reports.
#[derive(Debug, Clone)]
pub enum Json {
    Num(f64),
    Str(String),
    Bool(bool),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        match self {
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n}")
                }
            }
            Json::Bool(b) => b.to_string(),
            Json::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Json::Arr(a) => {
                format!("[{}]", a.iter().map(|v| v.render()).collect::<Vec<_>>().join(","))
            }
            Json::Obj(o) => format!(
                "{{{}}}",
                o.iter()
                    .map(|(k, v)| format!("\"{k}\":{}", v.render()))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

fn gen_json(g: &crate::traffic::gen::RwGen) -> Json {
    let s = &g.stats;
    Json::Obj(vec![
        ("name".into(), Json::Str(g.name().to_string())),
        ("issued".into(), Json::Num(s.issued as f64)),
        ("completed".into(), Json::Num(s.completed as f64)),
        ("bytes".into(), Json::Num(s.bytes as f64)),
        ("read_lat_mean".into(), Json::Num(s.read_latency.mean())),
        ("read_lat_p99".into(), Json::Num(s.read_latency.percentile(99.0) as f64)),
        ("write_lat_mean".into(), Json::Num(s.write_latency.mean())),
        ("data_errors".into(), Json::Num(s.data_errors as f64)),
    ])
}

fn slave_json(t: &SlaveTap) -> Json {
    Json::Obj(vec![
        ("name".into(), Json::Str(t.name.clone())),
        ("data_bytes".into(), Json::Num(t.data_bytes() as f64)),
    ])
}

/// Per-generator summary of a run.
pub fn run_report(sys: &System) -> Json {
    let gens: Vec<Json> = sys.gens.iter().map(|g| gen_json(&g.borrow())).collect();
    let slaves: Vec<Json> = sys.slave_taps.iter().map(slave_json).collect();
    let violations = sys.check_protocol();
    Json::Obj(vec![
        ("cycles".into(), Json::Num(sys.cycles as f64)),
        ("mode".into(), Json::Str(sys.mode_str().into())),
        ("components".into(), Json::Num(sys.component_count() as f64)),
        ("generators".into(), Json::Arr(gens)),
        ("slaves".into(), Json::Arr(slaves)),
        ("protocol_violations".into(), Json::Num(violations.len() as f64)),
    ])
}

/// Canonical rendering of everything the sleep/wake optimization must
/// leave unchanged: generator stats, per-slave byte counts, and the full
/// monitor violation streams. An event-mode and a full-scan run of the
/// same config must produce byte-identical fingerprints
/// (`rust/tests/coordinator_engine.rs`, `benches/coordinator_engine.rs`).
/// Engine-mode observables (`mode`, awake counts) are deliberately
/// excluded.
pub fn determinism_fingerprint(sys: &System) -> String {
    let gens: Vec<Json> = sys.gens.iter().map(|g| gen_json(&g.borrow())).collect();
    let slaves: Vec<Json> = sys.slave_taps.iter().map(slave_json).collect();
    let violations: Vec<Json> = sys
        .check_protocol()
        .iter()
        .map(|v| {
            Json::Obj(vec![
                ("cycle".into(), Json::Num(v.cycle as f64)),
                ("rule".into(), Json::Str(v.rule.to_string())),
                ("detail".into(), Json::Str(v.detail.clone())),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("cycles".into(), Json::Num(sys.cycles as f64)),
        ("generators".into(), Json::Arr(gens)),
        ("slaves".into(), Json::Arr(slaves)),
        ("violations".into(), Json::Arr(violations)),
    ])
    .render()
}

/// Human-readable run summary.
pub fn run_summary(sys: &System) -> String {
    let mut out = format!(
        "run: {} cycles ({} engine, {} components, {} awake at end)\n",
        sys.cycles,
        sys.mode_str(),
        sys.component_count(),
        sys.awake_components()
    );
    out.push_str(&format!(
        "{:<12}{:>8}{:>10}{:>12}{:>14}{:>14}{:>8}\n",
        "generator", "issued", "done", "bytes", "rd lat mean", "wr lat mean", "errs"
    ));
    for g in &sys.gens {
        let g = g.borrow();
        let s = &g.stats;
        out.push_str(&format!(
            "{:<12}{:>8}{:>10}{:>12}{:>14.1}{:>14.1}{:>8}\n",
            g.name(),
            s.issued,
            s.completed,
            s.bytes,
            s.read_latency.mean(),
            s.write_latency.mean(),
            s.data_errors
        ));
    }
    let v = sys.check_protocol();
    out.push_str(&format!("protocol violations: {}\n", v.len()));
    out
}

// The generator needs a name accessor for reports.
impl crate::traffic::gen::RwGen {
    pub fn name(&self) -> &str {
        crate::sim::Component::name(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_renders() {
        let j = Json::Obj(vec![
            ("a".into(), Json::Num(1.0)),
            ("b".into(), Json::Str("x\"y".into())),
            ("c".into(), Json::Arr(vec![Json::Bool(true), Json::Num(2.5)])),
        ]);
        assert_eq!(j.render(), r#"{"a":1,"b":"x\"y","c":[true,2.5]}"#);
    }

    #[test]
    fn report_over_built_system() {
        let cfg = crate::coordinator::config::SimCfg::from_str_toml(
            r#"
[sim]
cycles = 10000
[[master]]
total = 50
span = 0x1000
[[slave]]
kind = "perfect"
base = 0x0
size = 0x1000
"#,
        )
        .unwrap();
        let mut sys = System::build(&cfg).unwrap();
        sys.run(cfg.cycles);
        let j = run_report(&sys).render();
        assert!(j.contains("\"completed\":50"), "{j}");
        assert!(j.contains("\"mode\":\"event\""), "{j}");
        assert!(j.contains("\"slaves\":["), "{j}");
        let s = run_summary(&sys);
        assert!(s.contains("protocol violations: 0"));
        assert!(s.contains("event engine"), "{s}");
        let fp = determinism_fingerprint(&sys);
        assert!(fp.contains("\"violations\":[]"), "{fp}");
        assert!(!fp.contains("\"mode\""), "fingerprint must not depend on engine mode: {fp}");
    }
}
