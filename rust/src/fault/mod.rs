//! Deterministic, seeded fault injection (and the primitives recovery is
//! built from).
//!
//! A [`FaultPlan`] describes every fault a run injects:
//!
//! * **Beat errors** — per-beat corruption or loss on the data channels
//!   (W/R) of `noc::d2d::Die2Die` links, at probability
//!   [`FaultPlan::rate`]. Each link derives its own [`LinkFault`] stream
//!   from `seed ^ fnv1a(link_name)`, and the RNG is advanced **only on
//!   beat events** (accept and retransmit), never on idle ticks — so the
//!   injected fault sequence is a pure function of the beat stream
//!   through that link, which the engine already guarantees is identical
//!   across `--threads N` and event/full-scan modes. Recovery is the
//!   link-layer CRC + replay in `noc::d2d`.
//! * **Dead link** — a named D2D link stops accepting and delivering at
//!   cycle `at`. Nothing recovers from this; the point is that the run
//!   aborts through `sim::watchdog` with a diagnostic dump instead of
//!   spinning forever.
//! * **SLVERR window** — memory endpoints handed the plan answer
//!   [`crate::protocol::Resp::SlvErr`] for any burst touching
//!   `[base, base+len)`, optionally only until cycle `until` (a
//!   transient fault the DMA retry path can ride out).
//!
//! The module also hosts [`crc32`] (the link-layer checksum) and the
//! [`rogue`] drivers — deliberately non-compliant bundle endpoints used
//! by the *positive* protocol-monitor tests.

use std::collections::HashMap;

use crate::errors::{Context, Result};
use crate::sim::{Cycle, SplitMix64};

/// What happens to a data beat that draws a fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BeatFaultKind {
    /// One payload bit is flipped in flight; the receiver's CRC check
    /// catches it and NAKs.
    #[default]
    Corrupt,
    /// The beat is lost in flight; the receiver's arrival timeout
    /// catches it and NAKs.
    Drop,
}

/// The fault actually injected on one beat (reported back so the link
/// can split its `retransmits` / `dropped` counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BeatFault {
    Corrupted,
    Dropped,
}

/// A named link that dies mid-run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeadLink {
    /// `Die2Die` component name, e.g. `pod.d2d.0to1`.
    pub link: String,
    /// First cycle the link is dead (accepts and delivers nothing).
    pub at: Cycle,
}

/// Address window a faulted memory endpoint answers with SLVERR.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlvErrWindow {
    pub base: u64,
    pub len: u64,
    /// Fault clears at this cycle (`None` = permanent). A transient
    /// window exercises the DMA retry path end to end; a permanent one
    /// exercises the bounded-abort path.
    pub until: Option<Cycle>,
}

impl SlvErrWindow {
    /// Whether a beat at `addr` on cycle `cy` hits the (still-armed)
    /// window.
    pub fn hits(&self, addr: u64, cy: Cycle) -> bool {
        self.until.map_or(true, |t| cy < t)
            && addr >= self.base
            && addr < self.base.wrapping_add(self.len)
    }
}

/// Everything a run injects. Construct directly, or parse the CLI
/// surface with [`FaultPlan::from_flags`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Root seed; each link folds its name in via [`fnv1a`].
    pub seed: u64,
    /// Per-data-beat fault probability on D2D links.
    pub rate: f64,
    pub kind: BeatFaultKind,
    pub dead_link: Option<DeadLink>,
    pub slverr: Option<SlvErrWindow>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { seed: 1, rate: 0.0, kind: BeatFaultKind::Corrupt, dead_link: None, slverr: None }
    }
}

impl FaultPlan {
    /// A plan that corrupts (or drops) D2D data beats at `rate`.
    pub fn beat_errors(seed: u64, rate: f64, kind: BeatFaultKind) -> Self {
        FaultPlan { seed, rate, kind, ..FaultPlan::default() }
    }

    /// A plan that kills one named link at `at`.
    pub fn dead_link(link: impl Into<String>, at: Cycle) -> Self {
        FaultPlan {
            dead_link: Some(DeadLink { link: link.into(), at }),
            ..FaultPlan::default()
        }
    }

    /// Parse the `--fault-*` CLI surface; `None` when no fault flag is
    /// present. Flags:
    ///
    /// * `--fault-rate R` — per-beat D2D data-channel fault probability
    /// * `--fault-seed S` — injection seed (default 1)
    /// * `--fault-kind corrupt|drop|dead-link|slverr` (default corrupt)
    /// * `--fault-link NAME --fault-at CYCLE` — dead-link target
    /// * `--fault-addr A --fault-len N [--fault-until CYCLE]` — SLVERR
    ///   window (addresses accept a `0x` prefix)
    pub fn from_flags(flags: &HashMap<String, String>) -> Result<Option<FaultPlan>> {
        let touched = ["fault-rate", "fault-seed", "fault-kind", "fault-link", "fault-addr"]
            .iter()
            .any(|k| flags.contains_key(*k));
        if !touched {
            return Ok(None);
        }
        let mut plan = FaultPlan::default();
        if let Some(s) = flags.get("fault-seed") {
            plan.seed = s.parse().context("--fault-seed must be a u64")?;
        }
        if let Some(r) = flags.get("fault-rate") {
            plan.rate = r.parse().context("--fault-rate must be a probability")?;
            crate::ensure!(
                (0.0..1.0).contains(&plan.rate),
                "--fault-rate must be in [0, 1), got {}",
                plan.rate
            );
        }
        let kind = flags.get("fault-kind").map(|s| s.as_str()).unwrap_or("corrupt");
        match kind {
            "corrupt" => plan.kind = BeatFaultKind::Corrupt,
            "drop" => plan.kind = BeatFaultKind::Drop,
            "dead-link" => {
                let link = flags
                    .get("fault-link")
                    .context("--fault-kind dead-link requires --fault-link NAME")?
                    .clone();
                let at = match flags.get("fault-at") {
                    Some(v) => v.parse().context("--fault-at must be a cycle count")?,
                    None => 0,
                };
                plan.dead_link = Some(DeadLink { link, at });
            }
            "slverr" => {
                let base = parse_addr(
                    flags.get("fault-addr").context("--fault-kind slverr requires --fault-addr")?,
                )?;
                let len = parse_addr(
                    flags.get("fault-len").context("--fault-kind slverr requires --fault-len")?,
                )?;
                let until = flags
                    .get("fault-until")
                    .map(|v| v.parse().context("--fault-until must be a cycle count"))
                    .transpose()?;
                plan.slverr = Some(SlvErrWindow { base, len, until });
            }
            other => crate::bail!("unknown --fault-kind: {other} (corrupt|drop|dead-link|slverr)"),
        }
        Ok(Some(plan))
    }

    /// The per-link injector for a named link. Seeded from
    /// `seed ^ fnv1a(name)` so each link's fault stream is independent
    /// of every other link's traffic — the shard-confinement that keeps
    /// injection thread-count-invariant.
    pub fn link_fault(&self, link_name: &str) -> LinkFault {
        let dead_at = self
            .dead_link
            .as_ref()
            .filter(|d| d.link == link_name)
            .map(|d| d.at);
        LinkFault {
            rng: SplitMix64::new(self.seed ^ fnv1a(link_name.as_bytes())),
            rate: self.rate,
            kind: self.kind,
            dead_at,
        }
    }
}

fn parse_addr(s: &str) -> Result<u64> {
    let v = if let Some(hex) = s.strip_prefix("0x") {
        u64::from_str_radix(hex, 16)
    } else {
        s.parse()
    };
    v.with_context(|| format!("bad address/length: {s}"))
}

/// Per-link fault stream, derived via [`FaultPlan::link_fault`]. Owned
/// by the link component, so it lives and rolls inside one shard.
#[derive(Debug, Clone)]
pub struct LinkFault {
    rng: SplitMix64,
    rate: f64,
    kind: BeatFaultKind,
    dead_at: Option<Cycle>,
}

impl LinkFault {
    /// Whether the link is dead at `cy`.
    pub fn dead(&self, cy: Cycle) -> bool {
        self.dead_at.is_some_and(|t| cy >= t)
    }

    /// Whether this link is configured to die at some cycle
    /// (diagnostics only).
    pub fn will_die(&self) -> bool {
        self.dead_at.is_some()
    }

    /// Roll the per-beat fault and apply it to `data` (corruption flips
    /// one payload bit in place; the caller keeps the clean copy in its
    /// replay buffer). Call ONLY on beat transmission events — never on
    /// idle ticks — so the stream stays engine-mode- and thread-count-
    /// invariant.
    pub fn corrupt_or_drop(&mut self, data: &mut crate::protocol::payload::Bytes) -> Option<BeatFault> {
        if self.rate <= 0.0 || !self.rng.chance(self.rate) {
            return None;
        }
        match self.kind {
            BeatFaultKind::Drop => Some(BeatFault::Dropped),
            BeatFaultKind::Corrupt => {
                if data.is_empty() {
                    // Nothing to flip; model as a drop so the fault
                    // still exists (and still NAKs).
                    return Some(BeatFault::Dropped);
                }
                let bit = self.rng.below(data.len() as u64 * 8) as usize;
                data.as_mut_slice()[bit / 8] ^= 1 << (bit % 8);
                Some(BeatFault::Corrupted)
            }
        }
    }
}

/// FNV-1a 64-bit hash (stable across runs/platforms; used to fold link
/// names into the fault seed).
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// CRC-32 (IEEE 802.3, reflected, init/final `!0`) — the link-layer
/// checksum sealing every D2D data beat when fault injection is armed.
/// Bitwise (no table): it only runs on faulted links' data beats.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = !0;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// Deliberately non-compliant bundle drivers, for *positive* protocol-
/// monitor tests: each method produces exactly one class of violation
/// the monitor must report. These never appear in a real topology.
pub mod rogue {
    use crate::protocol::payload::{BBeat, Bytes, Cmd, Id, Resp, TxnTag, WBeat};
    use crate::protocol::port::{MasterEnd, SlaveEnd};
    use crate::sim::Cycle;

    /// A master that violates write ordering.
    pub struct RogueMaster {
        pub end: MasterEnd,
    }

    impl RogueMaster {
        /// Push a W data beat with no AW outstanding — the (O3)
        /// "W beat with no outstanding AW" violation.
        pub fn w_before_aw(&self, cy: Cycle, tag: TxnTag) {
            self.end.set_now(cy);
            self.end.w.push(WBeat::full(Bytes::zeroed(8), true, tag));
        }

        /// A well-formed single-beat write (AW then W), for setting up
        /// outstanding state before a rogue response.
        pub fn clean_write(&self, cy: Cycle, id: Id, addr: u64, tag: TxnTag) {
            self.end.set_now(cy);
            let mut c = Cmd::new(id, addr, 0, 3);
            c.tag = tag;
            self.end.aw.push(c);
            self.end.w.push(WBeat::full(Bytes::zeroed(8), true, tag));
        }

        /// Drain any responses so channels never back up.
        pub fn drain(&self, cy: Cycle) {
            self.end.set_now(cy);
            while self.end.b.can_pop() {
                self.end.b.pop();
            }
            while self.end.r.can_pop() {
                self.end.r.pop();
            }
        }
    }

    /// A slave that violates response ordering.
    pub struct RogueSlave {
        pub end: SlaveEnd,
    }

    impl RogueSlave {
        /// Absorb whatever commands/data arrived (a compliant sink).
        pub fn absorb(&self, cy: Cycle) {
            self.end.set_now(cy);
            while self.end.aw.can_pop() {
                self.end.aw.pop();
            }
            while self.end.w.can_pop() {
                self.end.w.pop();
            }
            while self.end.ar.can_pop() {
                self.end.ar.pop();
            }
        }

        /// Push a B response carrying an arbitrary (id, tag) — used to
        /// answer out of command order (the (O2) violation) or for an
        /// ID with nothing outstanding.
        pub fn b(&self, cy: Cycle, id: Id, tag: TxnTag) {
            self.end.set_now(cy);
            self.end.b.push(BBeat { id, resp: Resp::Okay, tag });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::payload::Bytes;

    #[test]
    fn crc32_known_vectors() {
        // Standard check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // One flipped bit always changes the CRC.
        let a = crc32(&[0u8; 64]);
        let mut buf = [0u8; 64];
        buf[17] ^= 0x10;
        assert_ne!(crc32(&buf), a);
    }

    #[test]
    fn fnv1a_distinguishes_link_names() {
        assert_ne!(fnv1a(b"pod.d2d.0to1"), fnv1a(b"pod.d2d.1to0"));
        assert_eq!(fnv1a(b"x"), fnv1a(b"x"));
    }

    #[test]
    fn link_fault_streams_are_per_link_and_deterministic() {
        let plan = FaultPlan::beat_errors(7, 0.5, BeatFaultKind::Corrupt);
        let roll = |name: &str| {
            let mut f = plan.link_fault(name);
            let mut out = Vec::new();
            for _ in 0..64 {
                let mut d = Bytes::zeroed(8);
                out.push((f.corrupt_or_drop(&mut d).is_some(), d));
            }
            out
        };
        assert_eq!(roll("a"), roll("a"), "same link, same stream");
        assert_ne!(roll("a"), roll("b"), "independent per-link streams");
    }

    #[test]
    fn corrupt_flips_exactly_one_bit() {
        let plan = FaultPlan::beat_errors(3, 1.0, BeatFaultKind::Corrupt);
        let mut f = plan.link_fault("l");
        for _ in 0..32 {
            let mut d = Bytes::zeroed(16);
            assert_eq!(f.corrupt_or_drop(&mut d), Some(BeatFault::Corrupted));
            let ones: u32 = d.as_slice().iter().map(|b| b.count_ones()).sum();
            assert_eq!(ones, 1, "exactly one bit flipped");
        }
    }

    #[test]
    fn zero_rate_never_rolls() {
        let plan = FaultPlan::beat_errors(3, 0.0, BeatFaultKind::Drop);
        let mut f = plan.link_fault("l");
        for _ in 0..1000 {
            let mut d = Bytes::zeroed(8);
            assert_eq!(f.corrupt_or_drop(&mut d), None);
        }
    }

    #[test]
    fn dead_link_targets_only_the_named_link() {
        let plan = FaultPlan::dead_link("pod.d2d.0to1", 100);
        assert!(!plan.link_fault("pod.d2d.0to1").dead(99));
        assert!(plan.link_fault("pod.d2d.0to1").dead(100));
        assert!(!plan.link_fault("pod.d2d.1to0").dead(1_000_000));
    }

    #[test]
    fn slverr_window_hits() {
        let w = SlvErrWindow { base: 0x1000, len: 0x100, until: Some(500) };
        assert!(w.hits(0x1000, 0));
        assert!(w.hits(0x10FF, 499));
        assert!(!w.hits(0x1100, 0), "past the window");
        assert!(!w.hits(0xFFF, 0), "before the window");
        assert!(!w.hits(0x1000, 500), "fault cleared");
        let p = SlvErrWindow { base: 0, len: 8, until: None };
        assert!(p.hits(4, u64::MAX), "permanent window never clears");
    }

    fn flags(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn from_flags_roundtrip() {
        assert_eq!(FaultPlan::from_flags(&flags(&[])).unwrap(), None);
        let p = FaultPlan::from_flags(&flags(&[("fault-rate", "0.001"), ("fault-seed", "9")]))
            .unwrap()
            .unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.rate, 0.001);
        assert_eq!(p.kind, BeatFaultKind::Corrupt);
        let p = FaultPlan::from_flags(&flags(&[
            ("fault-kind", "dead-link"),
            ("fault-link", "pod.d2d.0to1"),
            ("fault-at", "1000"),
        ]))
        .unwrap()
        .unwrap();
        assert_eq!(p.dead_link, Some(DeadLink { link: "pod.d2d.0to1".into(), at: 1000 }));
        let p = FaultPlan::from_flags(&flags(&[
            ("fault-kind", "slverr"),
            ("fault-addr", "0x1000"),
            ("fault-len", "256"),
            ("fault-until", "400"),
        ]))
        .unwrap()
        .unwrap();
        assert_eq!(p.slverr, Some(SlvErrWindow { base: 0x1000, len: 256, until: Some(400) }));
        assert!(FaultPlan::from_flags(&flags(&[("fault-kind", "nope")])).is_err());
        assert!(FaultPlan::from_flags(&flags(&[("fault-rate", "1.5")])).is_err());
        assert!(FaultPlan::from_flags(&flags(&[("fault-kind", "dead-link")])).is_err());
    }
}
