//! Coordinator engine bench: the configured single-crossbar topology
//! under the activity-tracked engine vs the full-scan mode
//! (`SimCfg::full_scan`), mirroring `benches/tab2_manticore.rs` for the
//! `noc simulate` stack. Both modes simulate the *same* fixed cycle
//! window (traffic drains partway through, so the event engine gets to
//! sleep the finished generators, idle endpoints, and untouched crossbar
//! ports) and must produce byte-identical determinism fingerprints. CI
//! tracks `event_cycles_per_sec` / `speedup` via
//! `BENCH_coordinator_engine.json` (`scripts/check_bench_trend.py`).

use std::time::Instant;

use noc::bench_harness::{quick, section, Report};
use noc::coordinator::{determinism_fingerprint, SimCfg, System, TopoCfg};

/// A multi-master / multi-slave topology exercising all three traffic
/// patterns and endpoint kinds. Masters are spread over the lower half
/// of the slave ranges so the upper endpoints stay idle — the scan
/// avoidance the event engine is for.
fn cfg_text(masters: usize, slaves: usize, total: u64, window: u64) -> String {
    let span = 0x1_0000u64;
    let mut t = format!("[sim]\ncycles = {window}\ndata_bits = 64\nid_bits = 4\n");
    for m in 0..masters {
        let pattern = ["uniform", "sequential", "hotspot"][m % 3];
        let base = (m % (slaves / 2).max(1)) as u64 * span;
        let beats = if m % 2 == 0 { 1 } else { 4 };
        t.push_str(&format!(
            "[[master]]\nname = \"gen{m}\"\npattern = \"{pattern}\"\nbase = {base:#x}\n\
             span = {span:#x}\nreads = 0.6\nbeats = {beats}\ntotal = {total}\n\
             max_outstanding = 4\nids = 4\n"
        ));
    }
    for s in 0..slaves {
        let kind = ["perfect", "simplex", "duplex"][s % 3];
        let base = s as u64 * span;
        t.push_str(&format!(
            "[[slave]]\nname = \"mem{s}\"\nkind = \"{kind}\"\nbase = {base:#x}\nsize = {span:#x}\n"
        ));
        if kind == "duplex" {
            t.push_str("banks = 4\n");
        }
    }
    t
}

/// Build and run one mode over the full window; returns the finished
/// system and the wall seconds.
fn run_mode(text: &str, full_scan: bool) -> (System, f64) {
    let mut cfg = SimCfg::from_str_toml(text).expect("config");
    cfg.engine.full_scan = full_scan;
    let mut sys = System::build(&cfg).expect("build");
    let t0 = Instant::now();
    sys.run_for(cfg.cycles);
    let wall = t0.elapsed().as_secs_f64();
    assert!(sys.all_done(), "traffic must drain inside the window (full_scan={full_scan})");
    assert!(sys.check_protocol().is_empty(), "protocol must stay clean");
    (sys, wall)
}

fn main() {
    let mut report = Report::new("coordinator_engine");
    let (masters, slaves, total, window) = if quick() {
        (4, 6, 300, 10_000u64)
    } else {
        (16, 16, 2_000, 60_000u64)
    };
    let text = cfg_text(masters, slaves, total, window);

    section(&format!(
        "coordinator {masters}x{slaves} topology: event vs full-scan engine ({window} cycles)"
    ));
    let (event_sys, event_s) = run_mode(&text, false);
    let (scan_sys, scan_s) = run_mode(&text, true);
    assert_eq!(
        determinism_fingerprint(&event_sys),
        determinism_fingerprint(&scan_sys),
        "sleep/wake must be simulation-invisible"
    );

    let cycles = event_sys.cycles;
    let event_cps = cycles as f64 / event_s;
    let scan_cps = cycles as f64 / scan_s;
    let speedup = event_cps / scan_cps;
    println!(
        "full-scan engine:        {:>10.0} cycles/s  ({:.3}s wall, {} cycles, {} components)",
        scan_cps,
        scan_s,
        cycles,
        scan_sys.component_count()
    );
    println!(
        "activity-tracked engine: {:>10.0} cycles/s  ({:.3}s wall, {} awake at end)",
        event_cps,
        event_s,
        event_sys.awake_components()
    );
    println!("speedup: {speedup:.2}x");
    report.metric("event_cycles_per_sec", event_cps);
    report.metric("full_scan_cycles_per_sec", scan_cps);
    report.metric("speedup", speedup);
    report.metric("components", event_sys.component_count() as f64);
    report.metric("awake_at_end", event_sys.awake_components() as f64);
    // Wall-clock ratios are unreliable on shared CI runners in sub-second
    // quick mode; only enforce the floor in full mode (cf. tab2_manticore).
    if !quick() {
        assert!(
            speedup > 1.0,
            "event engine must not be slower than the full scan ({speedup:.2}x)"
        );
    }
    // Sharded engine over the same topology: each master island in its
    // own shard, crossbar + endpoints in shard 0. Recorded alongside the
    // engine-mode speedup so the profiler's stall fraction is visible
    // for the coordinator stack too (not trend-gated here; the gated
    // copy lives in BENCH_tab2_manticore.json).
    let mut cfg = SimCfg::from_str_toml(&text).expect("config");
    cfg.engine.threads = Some(4);
    cfg.engine.epoch = 8;
    let mut sys = System::build(&cfg).expect("build");
    let t0 = Instant::now();
    sys.run_for(cfg.cycles);
    let sharded_wall = t0.elapsed().as_secs_f64();
    assert!(sys.check_protocol().is_empty(), "sharded protocol must stay clean");
    let prof = sys.shard_profile().expect("sharded engine profiles");
    println!(
        "sharded engine (4 threads): {:>10.0} cycles/s  (stall frac {:.3})",
        cycles as f64 / sharded_wall,
        prof.exchange_stall_frac()
    );
    report.metric("sharded_cycles_per_sec", cycles as f64 / sharded_wall);
    report.metric("sharded_stall_frac", prof.exchange_stall_frac());

    // Topology-grammar presets (`examples/topologies/`): parse, build,
    // and run each heterogeneous-SoC example on the single-arena event
    // engine; CI tracks the aggregate throughput so grammar-built systems
    // (converter trunks included) don't quietly regress.
    section("topology presets: examples/topologies/*.toml");
    let preset_cycles: u64 = if quick() { 3_000 } else { 20_000 };
    let mut preset_wall = 0.0f64;
    let mut presets = 0u64;
    for name in ["coolidge", "biglittle", "hbm_spine"] {
        let path = format!("{}/examples/topologies/{name}.toml", env!("CARGO_MANIFEST_DIR"));
        let text = std::fs::read_to_string(&path).expect("preset file");
        let mut cfg = TopoCfg::from_str_toml(&text).expect("preset parses");
        cfg.engine.threads = Some(0); // wall-clock metric: keep it host-independent
        let mut sys = cfg.build().expect("preset builds");
        let t0 = Instant::now();
        sys.run(preset_cycles);
        let wall = t0.elapsed().as_secs_f64();
        assert!(sys.check_protocol().is_empty(), "preset {name}: protocol must stay clean");
        println!(
            "{name:>10}: {:>10.0} cycles/s  ({} components)",
            preset_cycles as f64 / wall,
            sys.component_count()
        );
        preset_wall += wall;
        presets += 1;
    }
    report.metric(
        "topology_presets_cycles_per_sec",
        (presets * preset_cycles) as f64 / preset_wall,
    );
    report.finish();
}
