//! Multi-chiplet pod collectives over D2D links (`manticore::pod`).
//!
//! Headline metric: `d2d_allreduce_bytes_per_cycle` — payload bytes per
//! simulated cycle for the hierarchical all-reduce on a 4-chiplet pod
//! with the default (bandwidth-constrained, quarter-width) D2D link —
//! recorded in `BENCH_multichip.json` and tracked by
//! `scripts/check_bench_trend.py`. The bench also asserts the
//! acceptance gates: on 4 chiplets under a constrained link the
//! hierarchical schedule must beat the flat-ring oracle's bytes/cycle
//! AND move strictly fewer bytes over the D2D links (simulated cycles
//! are deterministic, so neither gate can flake on a noisy runner).
//!
//! Sweeps: chiplet count, D2D serialization (bandwidth), D2D latency.

use noc::bench_harness::{quick, section, Report};
use noc::manticore::chiplet::ChipletCfg;
use noc::manticore::pod::{run_pod_collective, Pod, PodCfg, PodCollectiveResult};
use noc::noc::d2d::D2DCfg;
use noc::sim::EngineOpts;

/// Simulation-cycle budget shared by every pod run in this bench.
const BUDGET: u64 = 50_000_000;

fn die() -> ChipletCfg {
    // 2 clusters/die in quick mode, 4 in full — the same code path as
    // the paper-scale die, scaled for bench wall time.
    let fanout = if quick() { vec![2] } else { vec![2, 2] };
    let engine = EngineOpts::sharded(4, 8);
    ChipletCfg { fanout, engine, ..ChipletCfg::full() }
}

fn payload() -> u64 {
    if quick() {
        16 * 1024
    } else {
        32 * 1024
    }
}

fn run(chiplets: usize, d2d: D2DCfg, bytes: u64, hier: bool) -> PodCollectiveResult {
    let mut pod =
        Pod::new(PodCfg { n_chiplets: chiplets, die: die(), d2d, fault: None, watchdog: 0 });
    let r = run_pod_collective(&mut pod, bytes, BUDGET, hier).expect("pod collective builds");
    assert!(r.finished, "pod all-reduce (chiplets={chiplets}, hier={hier}) must finish");
    assert!(r.correct, "pod all-reduce (chiplets={chiplets}, hier={hier}) must be exact");
    r
}

fn show(label: &str, r: &PodCollectiveResult) {
    println!(
        "{label:<36} {:>9} cycles  {:>7.2} B/cycle  {:>9} B over D2D",
        r.cycles, r.bytes_per_cycle, r.d2d_bytes
    );
}

fn main() {
    let mut report = Report::new("multichip");
    let bytes = payload();
    let d2d = D2DCfg::default(); // 50-cycle flight, quarter-width link
    let m = die().n_clusters();

    section(&format!(
        "4-chiplet pod ({m} clusters/die), {bytes} B all-reduce, \
         D2D latency {} / serialize {}",
        d2d.latency, d2d.serialize
    ));
    let hier = run(4, d2d, bytes, true);
    show("hierarchical (RS / D2D ring / AG)", &hier);
    let flat = run(4, d2d, bytes, false);
    show("flat ring (die-major oracle)", &flat);
    report.metric("d2d_allreduce_bytes_per_cycle", hier.bytes_per_cycle);
    report.metric("d2d_allreduce_cycles", hier.cycles as f64);
    report.metric("d2d_allreduce_d2d_bytes", hier.d2d_bytes as f64);
    report.metric("flat_allreduce_bytes_per_cycle", flat.bytes_per_cycle);
    report.metric("flat_allreduce_d2d_bytes", flat.d2d_bytes as f64);
    report.metric("hier_over_flat_speedup", hier.bytes_per_cycle / flat.bytes_per_cycle);

    section("chiplet-count sweep (hierarchical)");
    for nc in [2usize, 8] {
        let r = run(nc, d2d, bytes, true);
        show(&format!("{nc} chiplets ({} ranks)", nc * m), &r);
        report.metric(format!("hier_bytes_per_cycle_{nc}chiplets"), r.bytes_per_cycle);
    }

    section("D2D bandwidth sweep (serialize cycles per data beat)");
    for ser in [1u64, 8] {
        let cfg = D2DCfg { serialize: ser, ..d2d };
        let h = run(4, cfg, bytes, true);
        let f = run(4, cfg, bytes, false);
        show(&format!("serialize {ser}: hierarchical"), &h);
        show(&format!("serialize {ser}: flat ring"), &f);
        report.metric(format!("hier_bytes_per_cycle_ser{ser}"), h.bytes_per_cycle);
        report.metric(format!("flat_bytes_per_cycle_ser{ser}"), f.bytes_per_cycle);
    }

    section("D2D latency sweep (hierarchical)");
    for lat in [10u64, 200] {
        let cfg = D2DCfg { latency: lat, ..d2d };
        let r = run(4, cfg, bytes, true);
        show(&format!("latency {lat}"), &r);
        report.metric(format!("hier_bytes_per_cycle_lat{lat}"), r.bytes_per_cycle);
    }

    // Acceptance gates (deterministic — simulated cycles and byte
    // counters): with the constrained default link, the hierarchical
    // schedule beats the flat-ring oracle on throughput and moves
    // strictly fewer bytes off-die (2·(d−1)·B vs ~2·d·B).
    assert!(
        hier.bytes_per_cycle >= flat.bytes_per_cycle,
        "hierarchical must not lose to the flat ring on a constrained link: {:.2} vs {:.2} B/cycle",
        hier.bytes_per_cycle,
        flat.bytes_per_cycle
    );
    assert!(
        hier.d2d_bytes < flat.d2d_bytes,
        "hierarchical must cut off-die traffic: {} vs {} B",
        hier.d2d_bytes,
        flat.d2d_bytes
    );
    println!(
        "\nhierarchical: {:.2}x flat-ring throughput, {:.0}% of its D2D traffic \
         (gates: >= 1.0x, < 100%)",
        hier.bytes_per_cycle / flat.bytes_per_cycle,
        100.0 * hier.d2d_bytes as f64 / flat.d2d_bytes as f64
    );
    report.finish();
}
