//! Collective-communication bandwidth: DMA-driven ring/tree collectives
//! over the Manticore chiplet (`rust/src/collective/`).
//!
//! Headline metric: `allreduce_bytes_per_cycle` — payload bytes per
//! simulated cycle for a ring all-reduce — recorded in
//! `BENCH_collective.json` and tracked by `scripts/check_bench_trend.py`.
//! The bench also asserts the acceptance bound: ring all-reduce must
//! achieve at least 50% of the ideal `2·(N−1)/N · bytes /
//! link-bandwidth` time (simulated cycles are deterministic, so this
//! gate cannot flake on a noisy runner).

use noc::bench_harness::{quick, section, Report};
use noc::collective::{hierarchical_order, Algo, CollOp};
use noc::manticore::chiplet::{Chiplet, ChipletCfg};
use noc::manticore::workload::{run_collective, run_collective_with_order, CollectiveResult};
use noc::sim::EngineOpts;

fn bench_fanout() -> Vec<usize> {
    if quick() {
        vec![2, 2, 2] // 8 clusters — the acceptance configuration
    } else {
        vec![4, 4] // 16 clusters
    }
}

/// Simulation-cycle budget shared by every collective run in this bench.
const BUDGET: u64 = 20_000_000;

fn chiplet(threads: usize) -> Chiplet {
    let engine = EngineOpts { threads: Some(threads), ..EngineOpts::default() };
    Chiplet::new(ChipletCfg { fanout: bench_fanout(), engine, ..ChipletCfg::full() })
}

fn checked(op: CollOp, algo: Algo, res: CollectiveResult) -> CollectiveResult {
    assert!(res.finished, "{op:?}/{algo:?} must finish");
    assert!(res.correct, "{op:?}/{algo:?} must produce the exact result on every rank");
    res
}

/// Run one collective through the product path (`run_collective`, which
/// applies the hierarchy-aware ring mapping).
fn run(op: CollOp, algo: Algo, bytes: u64, threads: usize) -> CollectiveResult {
    let mut ch = chiplet(threads);
    let res = run_collective(&mut ch, op, algo, bytes, BUDGET).expect("collective builds");
    checked(op, algo, res)
}

/// Same chiplet/budget/assertions, but with the explicit linear
/// rank-r-equals-cluster-r ring order — the comparison side of the
/// mapping-delta metric.
fn run_linear(op: CollOp, algo: Algo, bytes: u64, threads: usize) -> CollectiveResult {
    let mut ch = chiplet(threads);
    let res = run_collective_with_order(&mut ch, op, algo, bytes, BUDGET, None)
        .expect("collective builds");
    checked(op, algo, res)
}

fn main() {
    let mut report = Report::new("collective");
    let bytes = 48 * 1024u64;
    let n: usize = bench_fanout().iter().product();

    section(&format!("ring vs tree collectives, {n} clusters, {bytes} B payload"));
    let mut show = |label: &str, r: &CollectiveResult| {
        println!(
            "{label:<28} {:>8} cycles  {:>7.2} B/cycle  ({:>3.0}% of ideal {:.2})",
            r.cycles,
            r.bytes_per_cycle,
            100.0 * r.ideal_fraction,
            r.ideal_bytes_per_cycle
        );
    };

    let ring = run(CollOp::AllReduce, Algo::Ring, bytes, 0);
    show("allreduce ring", &ring);
    report.metric("allreduce_bytes_per_cycle", ring.bytes_per_cycle);
    report.metric("allreduce_ideal_fraction", ring.ideal_fraction);
    report.metric("allreduce_cycles", ring.cycles as f64);

    // Ring mapping: the default runs use the hierarchy-aware order. The
    // chiplet numbers clusters contiguously per quadrant, so that order
    // is the identity today and a separate linear-map run would simulate
    // the exact same schedule — skip the duplicate simulation and record
    // a 0.0 delta directly. If `hierarchical_order` ever diverges from
    // the identity (a builder leaf-map change), this branch measures the
    // linear map for real and the delta becomes meaningful (simulated
    // cycles, deterministic either way).
    let identity: Vec<usize> = (0..n).collect();
    let linear = if hierarchical_order(&bench_fanout()) == identity {
        println!("allreduce ring (linear map): identical schedule, run skipped");
        None
    } else {
        Some(run_linear(CollOp::AllReduce, Algo::Ring, bytes, 0))
    };
    if let Some(r) = &linear {
        show("allreduce ring (linear map)", r);
    }
    let linear_bpc = linear.as_ref().map_or(ring.bytes_per_cycle, |r| r.bytes_per_cycle);
    report.metric("allreduce_linear_map_bytes_per_cycle", linear_bpc);
    report.metric("allreduce_ring_map_delta_bytes_per_cycle", ring.bytes_per_cycle - linear_bpc);

    // The tree needs two full-payload scratch slots per rank, so it runs
    // a smaller payload to stay inside the 128 KiB L1.
    let tree = run(CollOp::AllReduce, Algo::Tree, bytes / 2, 0);
    show("allreduce tree (24 KiB)", &tree);
    report.metric("tree_allreduce_bytes_per_cycle", tree.bytes_per_cycle);

    let bcast = run(CollOp::Broadcast, Algo::Ring, bytes, 0);
    show("broadcast ring (pipelined)", &bcast);
    report.metric("broadcast_bytes_per_cycle", bcast.bytes_per_cycle);

    let rs = run(CollOp::ReduceScatter, Algo::Ring, bytes, 0);
    show("reduce-scatter ring", &rs);
    report.metric("reduce_scatter_bytes_per_cycle", rs.bytes_per_cycle);

    section("sharded engine (4 threads): same ring all-reduce");
    let mut ch = chiplet(4);
    let res = run_collective(&mut ch, CollOp::AllReduce, Algo::Ring, bytes, BUDGET)
        .expect("collective builds");
    let sharded = checked(CollOp::AllReduce, Algo::Ring, res);
    show("allreduce ring --threads 4", &sharded);
    report.metric("sharded_allreduce_cycles", sharded.cycles as f64);
    // The per-shard cycle profiler's view of the same run: how much of
    // the workers' wall clock went to barrier stalls and exchanges.
    let prof = ch.shard_profile().expect("sharded engine profiles");
    report.metric("sharded_allreduce_stall_frac", prof.exchange_stall_frac());
    report.metric("sharded_allreduce_exchanges", prof.exchanges as f64);

    section("telemetry: energy accounting, same ring all-reduce with meters on");
    let engine = EngineOpts { threads: Some(0), telemetry: true, ..EngineOpts::default() };
    let cfg = ChipletCfg { fanout: bench_fanout(), engine, ..ChipletCfg::full() };
    let mut ch = Chiplet::new(cfg);
    let res = run_collective(&mut ch, CollOp::AllReduce, Algo::Ring, bytes, BUDGET)
        .expect("collective builds");
    let metered = checked(CollOp::AllReduce, Algo::Ring, res);
    assert_eq!(metered.cycles, ring.cycles, "telemetry must not change simulation results");
    println!(
        "allreduce energy: {:.1} pJ ({:.4} pJ/B payload); DMA chain latency p50 {} / p99 {} \
         cycles over {} chains",
        metered.energy_pj,
        metered.energy_per_byte_pj,
        metered.chain_latency.percentile(50.0),
        metered.chain_latency.percentile(99.0),
        metered.chain_latency.count()
    );
    report.metric("allreduce_energy_pj", metered.energy_pj);
    report.metric("energy_per_byte_pj", metered.energy_per_byte_pj);
    report.metric("allreduce_chain_p50_cycles", metered.chain_latency.percentile(50.0) as f64);
    report.metric("allreduce_chain_p99_cycles", metered.chain_latency.percentile(99.0) as f64);

    // Acceptance gate (deterministic — simulated cycles, not wall clock):
    // ring all-reduce sustains >= 50% of the ideal collective bound.
    assert!(
        ring.ideal_fraction >= 0.5,
        "ring all-reduce at {:.0}% of ideal (bound: 50%)",
        100.0 * ring.ideal_fraction
    );
    println!(
        "\nring all-reduce sustains {:.0}% of the ideal 2·(N−1)/N bound (gate: >= 50%)",
        100.0 * ring.ideal_fraction
    );
    report.finish();
}
