//! Collective-communication bandwidth: DMA-driven ring/tree collectives
//! over the Manticore chiplet (`rust/src/collective/`).
//!
//! Headline metric: `allreduce_bytes_per_cycle` — payload bytes per
//! simulated cycle for a ring all-reduce — recorded in
//! `BENCH_collective.json` and tracked by `scripts/check_bench_trend.py`.
//! The bench also asserts the acceptance bound: ring all-reduce must
//! achieve at least 50% of the ideal `2·(N−1)/N · bytes /
//! link-bandwidth` time (simulated cycles are deterministic, so this
//! gate cannot flake on a noisy runner).

use noc::bench_harness::{quick, section, Report};
use noc::collective::{Algo, CollOp};
use noc::manticore::chiplet::{Chiplet, ChipletCfg};
use noc::manticore::workload::{run_collective, CollectiveResult};

fn bench_fanout() -> Vec<usize> {
    if quick() {
        vec![2, 2, 2] // 8 clusters — the acceptance configuration
    } else {
        vec![4, 4] // 16 clusters
    }
}

fn run(op: CollOp, algo: Algo, bytes: u64, threads: usize) -> CollectiveResult {
    let cfg = ChipletCfg { fanout: bench_fanout(), threads, ..ChipletCfg::full() };
    let mut ch = Chiplet::new(cfg);
    let res = run_collective(&mut ch, op, algo, bytes, 20_000_000).expect("collective builds");
    assert!(res.finished, "{op:?}/{algo:?} must finish");
    assert!(res.correct, "{op:?}/{algo:?} must produce the exact result on every rank");
    res
}

fn main() {
    let mut report = Report::new("collective");
    let bytes = 48 * 1024u64;
    let n: usize = bench_fanout().iter().product();

    section(&format!("ring vs tree collectives, {n} clusters, {bytes} B payload"));
    let mut show = |label: &str, r: &CollectiveResult| {
        println!(
            "{label:<28} {:>8} cycles  {:>7.2} B/cycle  ({:>3.0}% of ideal {:.2})",
            r.cycles,
            r.bytes_per_cycle,
            100.0 * r.ideal_fraction,
            r.ideal_bytes_per_cycle
        );
    };

    let ring = run(CollOp::AllReduce, Algo::Ring, bytes, 0);
    show("allreduce ring", &ring);
    report.metric("allreduce_bytes_per_cycle", ring.bytes_per_cycle);
    report.metric("allreduce_ideal_fraction", ring.ideal_fraction);
    report.metric("allreduce_cycles", ring.cycles as f64);

    // The tree needs two full-payload scratch slots per rank, so it runs
    // a smaller payload to stay inside the 128 KiB L1.
    let tree = run(CollOp::AllReduce, Algo::Tree, bytes / 2, 0);
    show("allreduce tree (24 KiB)", &tree);
    report.metric("tree_allreduce_bytes_per_cycle", tree.bytes_per_cycle);

    let bcast = run(CollOp::Broadcast, Algo::Ring, bytes, 0);
    show("broadcast ring (pipelined)", &bcast);
    report.metric("broadcast_bytes_per_cycle", bcast.bytes_per_cycle);

    let rs = run(CollOp::ReduceScatter, Algo::Ring, bytes, 0);
    show("reduce-scatter ring", &rs);
    report.metric("reduce_scatter_bytes_per_cycle", rs.bytes_per_cycle);

    section("sharded engine (4 threads): same ring all-reduce");
    let sharded = run(CollOp::AllReduce, Algo::Ring, bytes, 4);
    show("allreduce ring --threads 4", &sharded);
    report.metric("sharded_allreduce_cycles", sharded.cycles as f64);

    // Acceptance gate (deterministic — simulated cycles, not wall clock):
    // ring all-reduce sustains >= 50% of the ideal collective bound.
    assert!(
        ring.ideal_fraction >= 0.5,
        "ring all-reduce at {:.0}% of ideal (bound: 50%)",
        100.0 * ring.ideal_fraction
    );
    println!(
        "\nring all-reduce sustains {:.0}% of the ideal 2·(N−1)/N bound (gate: >= 50%)",
        100.0 * ring.ideal_fraction
    );
    report.finish();
}
