//! Fig. 13: network multiplexer — minimum clock period and area for 2 to
//! 32 slave ports (6 ID bits), plus a cycle-level throughput validation of
//! the simulated mux (RR fairness means aggregate ~1 cmd/cycle).

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{bench, iters, section, Report};
use noc::protocol::payload::{Bytes, Cmd, RBeat, Resp};
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::Component;

fn sim_mux_throughput(s: usize, cycles: u64) -> f64 {
    let slave_cfg = BundleCfg::new(64, 6);
    let master_cfg = BundleCfg::new(64, 6 + noc::noc::prepend_bits(s));
    let mut ups = Vec::new();
    let mut downs = Vec::new();
    for i in 0..s {
        let (m, sl) = bundle(&format!("in{i}"), slave_cfg);
        ups.push(m);
        downs.push(sl);
    }
    let (master, out) = bundle("out", master_cfg);
    let mut mux = noc::noc::Mux::new("mux", downs, master);
    let mut delivered = 0u64;
    for cy in 1..=cycles {
        for u in &ups {
            u.set_now(cy);
            if u.ar.can_push() {
                u.ar.push(Cmd::new(0, 0x40, 0, 3));
            }
        }
        out.set_now(cy);
        mux.tick(cy);
        if out.ar.can_pop() {
            let c = out.ar.pop();
            out.r.push(RBeat { id: c.id, data: Bytes::zeroed(8), resp: Resp::Okay, last: true, tag: c.tag });
            delivered += 1;
        }
        for u in &ups {
            if u.r.can_pop() {
                u.r.pop();
            }
        }
    }
    delivered as f64 / cycles as f64
}

fn main() {
    let mut report = Report::new("fig13_mux");
    let cycles = iters(20_000, 2_000);

    // Paper series (area/timing model, calibrated to GF22FDX endpoints).
    for s in all_figures().iter().filter(|s| s.figure == "Fig 13") {
        println!("{}", s.render());
    }
    println!("paper endpoints: 190->270 ps, 2->30 kGE (S=2->32)");

    section("simulated mux: sustained command throughput (target ~1 cmd/cycle)");
    for s in [2usize, 4, 8, 16, 32] {
        let tput = sim_mux_throughput(s, cycles);
        let at = area_timing(Module::Mux { s, i: 6 });
        println!(
            "S={s:<3} cmd/cycle={tput:.3}  (model: {:.0} ps, {:.1} kGE, fmax {:.2} GHz)",
            at.cp_ps,
            at.kge,
            at.fmax_ghz()
        );
        assert!(tput > 0.9, "mux must sustain ~1 cmd/cycle, got {tput}");
        report.metric(format!("cmd_per_cycle_s{s}"), tput);
    }

    section("simulation speed");
    for s in [4usize, 32] {
        let t = report.timing(bench(
            &format!("mux S={s}, {cycles} cycles"),
            3,
            Some(cycles),
            || {
                sim_mux_throughput(s, cycles);
            },
        ));
        println!("{}", t.row());
    }
    report.finish();
}
