//! Fig. 21: duplex memory controller — (a) 8..1024-bit data width at two
//! memory ports, (b) 1..8 memory master ports at 64-bit, plus the
//! simulated duplex-vs-simplex bandwidth comparison and the banking-factor
//! conflict sweep the §2.7.2 discussion predicts.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{iters, section, Report};
use noc::noc::mem_duplex::{BankArray, MemDuplex};
use noc::protocol::payload::{Bytes, Cmd, WBeat};
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::{Component, SplitMix64};

/// Mixed read+write streams for `cycles`; returns (data beats, conflicts).
fn sim_duplex(banks: usize, cycles: u64) -> (u64, u64) {
    let (m, s) = bundle("p", BundleCfg::new(64, 4));
    let arr = BankArray::new(0, 1 << 20, banks, 8, 1);
    let mut ctrl = MemDuplex::new("mem", s, arr);
    let mut rng = SplitMix64::new(5);
    let mut beats = 0u64;
    let mut w_left = 0usize;
    for cy in 1..=cycles {
        m.set_now(cy);
        if w_left == 0 && m.aw.can_push() {
            let mut c = Cmd::new(0, rng.below(0x10000) & !7, 7, 3);
            c.tag = cy;
            m.aw.push(c);
            w_left = 8;
        }
        if w_left > 0 && m.w.can_push() {
            m.w.push(WBeat::full(Bytes::zeroed(8), w_left == 1, 0));
            w_left -= 1;
        }
        if m.ar.can_push() {
            let mut c = Cmd::new(1, rng.below(0x10000) & !7, 7, 3);
            c.tag = cy + 1_000_000;
            m.ar.push(c);
        }
        ctrl.tick(cy);
        if m.r.can_pop() {
            m.r.pop();
            beats += 1;
        }
        if m.b.can_pop() {
            m.b.pop();
        }
    }
    let conflicts = ctrl.banks.borrow().conflicts;
    (beats, conflicts)
}

fn main() {
    let mut report = Report::new("fig21_duplex");
    let cycles = iters(20_000, 4_000);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 21")) {
        println!("{}", s.render());
    }
    println!("paper: (a) 280->330 ps, 20->175 kGE; (b) ~300 ps, 28->34 kGE\n");

    section("simulated duplex: banking factor vs read throughput + conflicts");
    let mut last_conflicts = u64::MAX;
    for b in [2usize, 4, 8] {
        let (beats, conflicts) = sim_duplex(b, cycles);
        let at = area_timing(Module::MemDuplex { d: 64, b });
        report.metric(format!("r_beats_per_cycle_b{b}"), beats as f64 / cycles as f64);
        report.metric(format!("conflicts_b{b}"), conflicts as f64);
        println!(
            "B={b}: {:.3} R beats/cycle, {conflicts} conflicts  (model {:.0} ps, {:.1} kGE)",
            beats as f64 / cycles as f64,
            at.cp_ps,
            at.kge
        );
        assert!(
            conflicts <= last_conflicts,
            "higher banking factor must not increase conflicts"
        );
        last_conflicts = conflicts;
    }
    println!("\n(§2.7.2: increasing the banking factor reduces the conflict rate at the cost of more, shallower SRAM macros)");
    report.finish();
}
