//! Fig. 17: ID remapper — (a) U = 1..64 unique IDs at T = 8,
//! (b) T = 1..32 at U = 16, plus the paper's headline comparison: both
//! rightmost configurations remap 512 concurrent transactions, the
//! (U=16, T=32) one at ~2.6x lower area — and a simulated validation that
//! concurrency is capped at U·T per direction.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{iters, section, Report};
use noc::noc::id_remap::IdRemap;
use noc::protocol::payload::Cmd;
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::Component;

/// Issue reads (IDs cycling over U distinct values) without responding;
/// count how many pass through — must equal the U x T concurrency cap.
fn sim_max_concurrency(u: usize, t: u32, cycles: u64) -> u64 {
    let (up, up_s) = bundle("up", BundleCfg::new(64, 8));
    let (down_m, down_s) = bundle("down", BundleCfg::new(64, 8));
    let mut rm = IdRemap::new("rm", up_s, down_m, u, t);
    let mut passed = 0u64;
    let mut i = 0u64;
    for cy in 1..cycles {
        up.set_now(cy);
        if up.ar.can_push() {
            let mut c = Cmd::new((i % u as u64) as u32, 0, 0, 3);
            c.tag = i;
            up.ar.push(c);
            i += 1;
        }
        down_s.set_now(cy);
        rm.tick(cy);
        while down_s.ar.can_pop() {
            down_s.ar.pop();
            passed += 1;
        }
    }
    passed
}

fn main() {
    let mut report = Report::new("fig17_remap");
    let cycles = iters(4000, 1500);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 17")) {
        println!("{}", s.render());
    }
    println!("paper endpoints: (a) 200->640 ps, 1->41 kGE; (b) 300->440 ps, 7->16 kGE\n");

    // §3.3.1 headline: 512 txns either way; U=16/T=32 is ~2.6x smaller.
    let big = area_timing(Module::IdRemap { i: 6, u: 64, t: 8 });
    let small = area_timing(Module::IdRemap { i: 6, u: 16, t: 32 });
    println!(
        "512-txn configs: U=64/T=8 {:.1} kGE vs U=16/T=32 {:.1} kGE -> {:.1}x area (paper: 2.6x)\n",
        big.kge,
        small.kge,
        big.kge / small.kge
    );

    section("simulated concurrency cap (reads unanswered; U distinct IDs offered)");
    for (u, t) in [(1usize, 8u32), (4, 8), (16, 8), (16, 32), (64, 8)] {
        let passed = sim_max_concurrency(u, t, cycles);
        let cap = (u as u64) * (t as u64);
        report.metric(format!("forwarded_u{u}_t{t}"), passed as f64);
        println!("U={u:<3} T={t:<3} forwarded {passed:>4} (cap {cap})");
        assert!(passed <= cap, "remapper must cap concurrency at U*T");
        assert_eq!(passed, cap, "should reach the cap under pressure");
    }
    report.finish();
}
