//! Fig. 20: (a) DMA engine for 16..1024-bit data widths, (b) simplex
//! memory controller for 8..1024-bit, plus simulated DMA copy throughput
//! per width and the simplex controller's one-op-per-cycle ceiling.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{iters, section, Report};
use noc::noc::dma::{Dma, TransferReq};
use noc::noc::mem_duplex::{BankArray, MemDuplex};
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::Component;

fn sim_dma_copy(data_bits: usize, len: u64) -> f64 {
    let cfg = BundleCfg::new(data_bits, 4);
    let (m, s) = bundle("p", cfg);
    let banks = BankArray::new(0, 1 << 22, 8, cfg.beat_bytes(), 1);
    let mut dma = Dma::new("dma", m);
    let mut mem = MemDuplex::new("mem", s, banks);
    let h = dma.submit(TransferReq::OneD { src: 0x1000, dst: 0x200_000, len });
    let mut cy = 0u64;
    while !dma.completions.contains(&h) {
        cy += 1;
        dma.tick(cy);
        mem.tick(cy);
        assert!(cy < 10_000_000, "copy did not complete");
    }
    len as f64 / cy as f64
}

fn main() {
    let mut report = Report::new("fig20_dma_mem");
    let len = iters(256 * 1024, 64 * 1024);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 20")) {
        println!("{}", s.render());
    }
    println!("paper: DMA 290->400 ps / 25->141 kGE; simplex ~290 ps / 13->53 kGE\n");

    section("simulated DMA copy throughput vs data width");
    for bits in [64usize, 128, 256, 512, 1024] {
        let bpc = sim_dma_copy(bits, len);
        report.metric(format!("bytes_per_cycle_d{bits}"), bpc);
        let at = area_timing(Module::Dma { d: bits });
        let peak = (bits / 8) as f64;
        println!(
            "D={bits:<5} {bpc:>6.1} B/cycle ({:>3.0}% of {peak} B/cy beat rate)  (model {:.0} ps, {:.0} kGE)",
            100.0 * bpc / peak,
            at.cp_ps,
            at.kge
        );
        assert!(bpc / peak > 0.5, "DMA should stream at >50% of beat rate");
    }

    println!("\nsimplex controller (model; constant critical path in D):");
    for d in [8usize, 64, 256, 1024] {
        let at = area_timing(Module::MemSimplex { d });
        println!("  D={d}: {:.0} ps, {:.1} kGE", at.cp_ps, at.kge);
    }
    report.finish();
}
