//! Table 2: Manticore network implementation results — the modeled
//! area/power per level (cells from the §3 model, wire share anchored to
//! the published P&R values), validated against the simulated per-level
//! traffic distribution of a conv workload (the hierarchical design's
//! point: most bytes stay on the L1 networks).

use noc::bench_harness::section;
use noc::manticore::chiplet::{Chiplet, ChipletCfg};
use noc::manticore::perf::render_table2;
use noc::manticore::workload::{conv_scripts, run_scripts, ConvVariant, CONV_SMALL};

fn main() {
    println!("{}", render_table2());

    section("simulated per-level DMA-tree traffic (16 clusters, conv stacked vs pipelined)");
    for (label, variant) in
        [("stacked", ConvVariant::Stacked), ("pipelined", ConvVariant::Pipelined)]
    {
        let cfg = ChipletCfg { fanout: vec![4, 4], ..ChipletCfg::full() };
        let n = cfg.n_clusters();
        let mut ch = Chiplet::new(cfg);
        let scripts = conv_scripts(CONV_SMALL, variant, n, 8);
        let res = run_scripts(&mut ch, scripts, 50_000_000);
        assert!(res.finished, "{label} must finish");
        println!(
            "{label:<10} cycles={} cluster-ports={} B, uplink bytes per level (L1, L2): {:?}",
            res.cycles, res.cluster_dma_bytes, res.level_bytes
        );
    }
    println!(
        "\nthe pipelined variant moves inter-cluster traffic at the lowest level \
         (cf. paper: \"data ... is mainly transferred through the L1 networks\")"
    );
}
