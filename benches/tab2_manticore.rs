//! Table 2: Manticore network implementation results — the modeled
//! area/power per level (cells from the §3 model, wire share anchored to
//! the published P&R values), validated against the simulated per-level
//! traffic distribution of a conv workload (the hierarchical design's
//! point: most bytes stay on the L1 networks).
//!
//! This bench also carries the engine's headline perf measurement: the
//! same full-system conv run under the activity-tracked engine vs the
//! full-scan mode (`ChipletCfg::full_scan`), reporting simulated
//! cycles/second for both and the speedup — the number CI tracks via
//! `BENCH_tab2_manticore.json`.

use std::time::Instant;

use noc::bench_harness::{iters, quick, section, Report};
use noc::coordinator::Json;
use noc::manticore::chiplet::{determinism_fingerprint, Chiplet, ChipletCfg};
use noc::manticore::perf::render_table2;
use noc::manticore::workload::{
    conv_scripts, run_scripts, xsection_submit, ConvCfg, ConvVariant, WorkloadResult, CONV_SMALL,
};
use noc::sim::{EngineOpts, EpochPolicy, ShardProfileReport};

fn bench_fanout() -> Vec<usize> {
    if quick() {
        vec![2, 2]
    } else {
        vec![4, 4]
    }
}

fn bench_conv() -> ConvCfg {
    if quick() {
        ConvCfg { wi: 8, di: 16, k: 16, f: 3, p: 1, s: 1 }
    } else {
        CONV_SMALL
    }
}

/// Run the stacked-conv workload; returns the result and wall seconds.
fn conv_run(full_scan: bool, variant: ConvVariant, budget: u64) -> (WorkloadResult, f64) {
    conv_run_opts(EngineOpts { full_scan, ..EngineOpts::default() }, variant, budget)
}

fn conv_run_opts(engine: EngineOpts, variant: ConvVariant, budget: u64) -> (WorkloadResult, f64) {
    let cfg = ChipletCfg { fanout: bench_fanout(), engine, ..ChipletCfg::full() };
    let n = cfg.n_clusters();
    let mut ch = Chiplet::new(cfg);
    let scripts = conv_scripts(bench_conv(), variant, n, 8);
    let t0 = Instant::now();
    let res = run_scripts(&mut ch, scripts, budget);
    (res, t0.elapsed().as_secs_f64())
}

/// Fanout for the sharded sections: enough clusters (= shards) that the
/// CI thread count (`NOC_BENCH_THREADS=8`) still has real work per
/// worker even in quick mode.
fn shard_fanout() -> Vec<usize> {
    if quick() {
        vec![4, 2] // 8 clusters = 9 shards
    } else {
        vec![4, 4] // 16 clusters = 17 shards
    }
}

/// The cross-section workload on the sharded engine: every cluster
/// DMA-reads from and DMA-writes to a neighbour for a fixed window,
/// pre-submitted so the whole run is one parallel batch. Runs `total`
/// cycles (>= the traffic `window` — the excess is an idle tail the
/// adaptive policy sprints through). Returns the determinism
/// fingerprint, the wall seconds, and the accumulated shard profile.
fn sharded_xsection(
    threads: usize,
    window: u64,
    total: u64,
    policy: EpochPolicy,
) -> (String, f64, ShardProfileReport) {
    let engine = EngineOpts { policy, ..EngineOpts::sharded(threads, 16) };
    let cfg = ChipletCfg { fanout: shard_fanout(), engine, ..ChipletCfg::full() };
    let mut ch = Chiplet::new(cfg);
    xsection_submit(&ch, window);
    let t0 = Instant::now();
    ch.run(total);
    let wall = t0.elapsed().as_secs_f64();
    let prof = ch.shard_profile().expect("sharded engine profiles");
    (determinism_fingerprint(&ch), wall, prof)
}

/// Write the per-shard cycle profile as its own CI artifact
/// (`BENCH_tab2_shard_profile.json`). The raw per-shard `awake_integral`
/// and per-worker `exchange_ns` have been exported since the profiler
/// landed; what this adds on top are the *derived* balance views the raw
/// nanosecond columns bury: each shard's share of the total awake
/// integral (the LPT placement weight — a skewed distribution here means
/// placement is fighting real load imbalance) and each worker's
/// stall/exchange fractions of its own wall clock.
fn write_shard_profile(prof: &ShardProfileReport, threads: usize) {
    let awake_total: u64 = prof.shards.iter().map(|s| s.awake_integral).sum();
    let shards: Vec<Json> = prof
        .shards
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Json::Obj(vec![
                ("shard".into(), Json::Num(i as f64)),
                ("run_ns".into(), Json::Num(s.run_ns as f64)),
                ("windows".into(), Json::Num(s.windows as f64)),
                ("awake_integral".into(), Json::Num(s.awake_integral as f64)),
                (
                    "awake_share".into(),
                    Json::Num(s.awake_integral as f64 / awake_total.max(1) as f64),
                ),
            ])
        })
        .collect();
    let workers: Vec<Json> = prof
        .workers
        .iter()
        .enumerate()
        .map(|(i, w)| {
            let total = (w.run_ns + w.stall_ns + w.exchange_ns).max(1) as f64;
            Json::Obj(vec![
                ("worker".into(), Json::Num(i as f64)),
                ("run_ns".into(), Json::Num(w.run_ns as f64)),
                ("stall_ns".into(), Json::Num(w.stall_ns as f64)),
                ("exchange_ns".into(), Json::Num(w.exchange_ns as f64)),
                ("stall_frac".into(), Json::Num(w.stall_ns as f64 / total)),
                ("exchange_frac".into(), Json::Num(w.exchange_ns as f64 / total)),
            ])
        })
        .collect();
    let obj = Json::Obj(vec![
        ("bench".into(), Json::Str("tab2_shard_profile".into())),
        ("threads".into(), Json::Num(threads as f64)),
        ("awake_integral_total".into(), Json::Num(awake_total as f64)),
        ("runs".into(), Json::Num(prof.runs as f64)),
        ("sprints".into(), Json::Num(prof.sprints as f64)),
        ("exchanges".into(), Json::Num(prof.exchanges as f64)),
        ("groups_skipped".into(), Json::Num(prof.groups_skipped as f64)),
        ("groups_exchanged".into(), Json::Num(prof.groups_exchanged as f64)),
        ("placements_computed".into(), Json::Num(prof.placements_computed as f64)),
        ("exchange_stall_frac".into(), Json::Num(prof.exchange_stall_frac())),
        ("shards".into(), Json::Arr(shards)),
        ("workers".into(), Json::Arr(workers)),
    ]);
    let dir = std::env::var("NOC_BENCH_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::PathBuf::from(dir).join("BENCH_tab2_shard_profile.json");
    match std::fs::write(&path, obj.render() + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", path.display()),
    }
}

fn main() {
    let mut report = Report::new("tab2_manticore");
    let budget = iters(50_000_000, 5_000_000);

    println!("{}", render_table2());

    section("simulated per-level DMA-tree traffic (conv stacked vs pipelined)");
    for (label, variant) in
        [("stacked", ConvVariant::Stacked), ("pipelined", ConvVariant::Pipelined)]
    {
        let (res, _) = conv_run(false, variant, budget);
        assert!(res.finished, "{label} must finish");
        println!(
            "{label:<10} cycles={} cluster-ports={} B, uplink bytes per level (L1, L2): {:?}",
            res.cycles, res.cluster_dma_bytes, res.level_bytes
        );
        report.metric(format!("{label}_cycles"), res.cycles as f64);
        report.metric(format!("{label}_cluster_dma_bytes"), res.cluster_dma_bytes as f64);
    }
    println!(
        "\nthe pipelined variant moves inter-cluster traffic at the lowest level \
         (cf. paper: \"data ... is mainly transferred through the L1 networks\")"
    );

    section("engine throughput: activity-tracked vs full-scan (same workload)");
    // Warm up both paths once, then measure.
    let (event_res, event_s) = conv_run(false, ConvVariant::Stacked, budget);
    let (scan_res, scan_s) = conv_run(true, ConvVariant::Stacked, budget);
    assert!(event_res.finished && scan_res.finished);
    assert_eq!(
        (event_res.cycles, event_res.cluster_dma_bytes, &event_res.level_bytes),
        (scan_res.cycles, scan_res.cluster_dma_bytes, &scan_res.level_bytes),
        "sleep/wake must be simulation-invisible"
    );
    let event_cps = event_res.cycles as f64 / event_s;
    let scan_cps = scan_res.cycles as f64 / scan_s;
    let speedup = event_cps / scan_cps;
    println!(
        "full-scan engine:        {:>10.0} cycles/s  ({:.2}s wall, {} cycles)",
        scan_cps, scan_s, scan_res.cycles
    );
    println!(
        "activity-tracked engine: {:>10.0} cycles/s  ({:.2}s wall, {} cycles)",
        event_cps, event_s, event_res.cycles
    );
    println!("speedup: {speedup:.2}x (acceptance target: >= 2x)");
    report.metric("full_scan_cycles_per_sec", scan_cps);
    report.metric("event_cycles_per_sec", event_cps);
    report.metric("speedup", speedup);

    section("telemetry: per-inference energy (meters + trace rings on)");
    // Same stacked-conv inference with the telemetry layer attached. The
    // simulated outcome must be untouched (meters read `Activity`
    // returns the engine computes anyway), so the cycle counts are
    // asserted equal against the untraced run above.
    let telemetry_opts = EngineOpts { telemetry: true, ..EngineOpts::default() };
    let (tele_res, tele_s) = conv_run_opts(telemetry_opts.clone(), ConvVariant::Stacked, budget);
    assert!(tele_res.finished);
    assert_eq!(tele_res.cycles, event_res.cycles, "telemetry must be simulation-invisible");
    println!(
        "energy per inference: {:.1} pJ ({} cycles, {:.2}s wall with telemetry)",
        tele_res.energy_pj, tele_res.cycles, tele_s
    );
    report.metric("energy_per_inference_pj", tele_res.energy_pj);
    assert!(tele_res.energy_pj > 0.0, "telemetry-on run must account energy");
    // Telemetry cost: min-of-reps wall clock for the traced vs untraced
    // event-mode run. Min-of-3 because single quick-mode runs are well
    // inside shared-runner noise; the trend gate holds the ratio under
    // 5% (tracked as telemetry_overhead_frac, clamped at 0 so a noisy
    // faster-with-telemetry rep reports 0 overhead rather than negative).
    let mut plain_best = event_s;
    let mut tele_best = tele_s;
    for _ in 0..2 {
        plain_best = plain_best.min(conv_run(false, ConvVariant::Stacked, budget).1);
        let rep = conv_run_opts(telemetry_opts.clone(), ConvVariant::Stacked, budget).1;
        tele_best = tele_best.min(rep);
    }
    let telemetry_overhead_frac = (tele_best / plain_best - 1.0).max(0.0);
    println!(
        "telemetry overhead: {:.1}% (best-of-3: {:.3}s traced vs {:.3}s untraced)",
        100.0 * telemetry_overhead_frac,
        tele_best,
        plain_best
    );
    report.metric("telemetry_overhead_frac", telemetry_overhead_frac);

    section("core read latency probe (unloaded, single-beat reads across the tree)");
    {
        use noc::manticore::cluster::addr;
        use noc::traffic::gen::{AddrPattern, RwGenCfg};
        let cfg = ChipletCfg { fanout: bench_fanout(), ..ChipletCfg::full() };
        let n = cfg.n_clusters();
        let mut ch = Chiplet::new(cfg);
        ch.clusters[0].cores.borrow_mut().set_cfg(RwGenCfg {
            pattern: AddrPattern::Uniform { base: addr::cluster_base(n - 1), span: 0x1000 },
            p_read: 1.0,
            total: Some(64),
            max_outstanding: 1,
            verify: false,
            seed: 3,
            ..Default::default()
        });
        let ok = ch.run_until(1_000_000, |c| c.clusters[0].cores.borrow().done());
        assert!(ok, "latency probe must finish");
        let stats = ch.clusters[0].cores.borrow().stats.clone();
        let p50 = stats.read_latency.percentile(50.0);
        let p99 = stats.read_latency.percentile(99.0);
        println!(
            "read latency cluster 0 -> cluster {}: mean {:.1}, p50 {p50}, p99 {p99} cycles",
            n - 1,
            stats.read_latency.mean()
        );
        report.metric("read_latency_p50_cycles", p50 as f64);
        report.metric("read_latency_p99_cycles", p99 as f64);
    }

    section("sharded engine: persistent pool + weighted placement (xsection load)");
    // CI sets NOC_BENCH_THREADS=8, so the smoke artifact always carries
    // the {1, 8}-thread pair and the parallel_efficiency trend metric.
    // Values below 2 fall back to 8: against the built-in 1-thread run
    // they would make the fingerprint assert vacuous and the efficiency
    // a noise ratio of two identical measurements.
    let shard_threads = std::env::var("NOC_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 2)
        .unwrap_or(8);
    let window = iters(100_000, 10_000);
    let (fp1, wall1, _) = sharded_xsection(1, window, window, EpochPolicy::Fixed);
    let (fp_n, wall_n, prof_n) =
        sharded_xsection(shard_threads, window, window, EpochPolicy::Fixed);
    assert_eq!(fp1, fp_n, "sharded runs must be bit-identical across thread counts");
    let sharded_cps = window as f64 / wall_n;
    let sharded_cps_1t = window as f64 / wall1;
    // Cycles/sec at N threads over N x the 1-thread rate: 1.0 = linear
    // scaling. Same-workload wall-clock ratio, so runner speed cancels
    // out (runner *noise* does not — see the trend-check threshold).
    let parallel_efficiency = sharded_cps / (shard_threads as f64 * sharded_cps_1t);
    println!(
        "sharded engine ({shard_threads} threads): {:>10.0} cycles/s  \
         ({:.2}s wall; 1-thread {:.0} cycles/s, {:.2}s; {} cycles)",
        sharded_cps, wall_n, sharded_cps_1t, wall1, window
    );
    println!(
        "parallel efficiency: {:.2} (cycles/s at {shard_threads} threads / \
         {shard_threads} x 1-thread)",
        parallel_efficiency
    );
    report.metric("sharded_cycles_per_sec", sharded_cps);
    report.metric("sharded_cycles_per_sec_1t", sharded_cps_1t);
    report.metric("sharded_threads", shard_threads as f64);
    report.metric("parallel_efficiency", parallel_efficiency);
    // Where the wall clock went: fraction of worker time spent stalled
    // at the epoch barrier (vs running shards / exchanging queues).
    let stall = prof_n.exchange_stall_frac();
    println!(
        "exchange/barrier stall fraction: {stall:.3} ({} exchanges, {} clean groups skipped)",
        prof_n.exchanges, prof_n.groups_skipped
    );
    report.metric("exchange_stall_frac", stall);
    write_shard_profile(&prof_n, shard_threads);

    section("adaptive epochs: proven-idle boundaries sprint (fixed vs adaptive)");
    // Same traffic window plus a 3x idle tail: the fixed policy walks
    // every boundary of the tail, the adaptive policy proves the system
    // drained and fast-forwards. The fingerprints must stay
    // bit-identical — only the wall clock may differ.
    let tail_total = window * 4;
    let (fp_f, wall_f, prof_f) =
        sharded_xsection(shard_threads, window, tail_total, EpochPolicy::Fixed);
    let (fp_a, wall_a, prof_a) =
        sharded_xsection(shard_threads, window, tail_total, EpochPolicy::Adaptive);
    assert_eq!(fp_f, fp_a, "adaptive epochs must be simulation-invisible");
    let adaptive_epoch_speedup = wall_f / wall_a;
    println!(
        "fixed:    {:.3}s wall, {} exchanges, {} sprints",
        wall_f, prof_f.exchanges, prof_f.sprints
    );
    println!(
        "adaptive: {:.3}s wall, {} exchanges, {} sprints",
        wall_a, prof_a.exchanges, prof_a.sprints
    );
    println!("adaptive epoch speedup on the idle tail: {adaptive_epoch_speedup:.2}x");
    report.metric("adaptive_epoch_speedup", adaptive_epoch_speedup);
    report.metric("adaptive_sprints", prof_a.sprints as f64);
    report.metric("adaptive_exchanges", prof_a.exchanges as f64);
    report.metric("fixed_exchanges", prof_f.exchanges as f64);

    // Relay sleep: an idle sharded chiplet must be fully asleep between
    // exchanges — the cut relays were the last permanently-awake
    // components. Simulated state, not wall clock: deterministic.
    let idle_awake = {
        let engine = EngineOpts::sharded(2, 16);
        let cfg = ChipletCfg { fanout: bench_fanout(), engine, ..ChipletCfg::full() };
        let mut ch = Chiplet::new(cfg);
        ch.run(256);
        ch.awake_components()
    };
    println!("idle sharded chiplet awake components: {idle_awake}");
    report.metric("sharded_idle_awake_components", idle_awake as f64);
    assert_eq!(idle_awake, 0, "cut relays must sleep on an idle fabric");
    // Wall-clock assertions are unreliable on noisy shared CI runners with
    // sub-second quick-mode runs, so only enforce the floor in full mode;
    // the smoke job still records the metric in BENCH_tab2_manticore.json.
    if !quick() {
        assert!(
            speedup > 1.0,
            "activity tracking must not be slower than the full scan ({speedup:.2}x)"
        );
    }
    report.finish();
}
