//! Table 2: Manticore network implementation results — the modeled
//! area/power per level (cells from the §3 model, wire share anchored to
//! the published P&R values), validated against the simulated per-level
//! traffic distribution of a conv workload (the hierarchical design's
//! point: most bytes stay on the L1 networks).
//!
//! This bench also carries the engine's headline perf measurement: the
//! same full-system conv run under the activity-tracked engine vs the
//! full-scan mode (`ChipletCfg::full_scan`), reporting simulated
//! cycles/second for both and the speedup — the number CI tracks via
//! `BENCH_tab2_manticore.json`.

use std::time::Instant;

use noc::bench_harness::{iters, quick, section, Report};
use noc::manticore::chiplet::{determinism_fingerprint, Chiplet, ChipletCfg};
use noc::manticore::perf::render_table2;
use noc::manticore::workload::{
    conv_scripts, run_scripts, xsection_submit, ConvCfg, ConvVariant, WorkloadResult, CONV_SMALL,
};
use noc::sim::EngineOpts;

fn bench_fanout() -> Vec<usize> {
    if quick() {
        vec![2, 2]
    } else {
        vec![4, 4]
    }
}

fn bench_conv() -> ConvCfg {
    if quick() {
        ConvCfg { wi: 8, di: 16, k: 16, f: 3, p: 1, s: 1 }
    } else {
        CONV_SMALL
    }
}

/// Run the stacked-conv workload; returns the result and wall seconds.
fn conv_run(full_scan: bool, variant: ConvVariant, budget: u64) -> (WorkloadResult, f64) {
    let engine = EngineOpts { full_scan, ..EngineOpts::default() };
    let cfg = ChipletCfg { fanout: bench_fanout(), engine, ..ChipletCfg::full() };
    let n = cfg.n_clusters();
    let mut ch = Chiplet::new(cfg);
    let scripts = conv_scripts(bench_conv(), variant, n, 8);
    let t0 = Instant::now();
    let res = run_scripts(&mut ch, scripts, budget);
    (res, t0.elapsed().as_secs_f64())
}

/// The cross-section workload on the sharded engine: every cluster
/// DMA-reads from and DMA-writes to a neighbour for a fixed window,
/// pre-submitted so the whole run is one parallel batch. Returns the
/// determinism fingerprint and the wall seconds.
fn sharded_xsection(threads: usize, cycles: u64) -> (String, f64) {
    let engine = EngineOpts::sharded(threads, 16);
    let cfg = ChipletCfg { fanout: bench_fanout(), engine, ..ChipletCfg::full() };
    let mut ch = Chiplet::new(cfg);
    xsection_submit(&ch, cycles);
    let t0 = Instant::now();
    ch.run(cycles);
    (determinism_fingerprint(&ch), t0.elapsed().as_secs_f64())
}

fn main() {
    let mut report = Report::new("tab2_manticore");
    let budget = iters(50_000_000, 5_000_000);

    println!("{}", render_table2());

    section("simulated per-level DMA-tree traffic (conv stacked vs pipelined)");
    for (label, variant) in
        [("stacked", ConvVariant::Stacked), ("pipelined", ConvVariant::Pipelined)]
    {
        let (res, _) = conv_run(false, variant, budget);
        assert!(res.finished, "{label} must finish");
        println!(
            "{label:<10} cycles={} cluster-ports={} B, uplink bytes per level (L1, L2): {:?}",
            res.cycles, res.cluster_dma_bytes, res.level_bytes
        );
        report.metric(format!("{label}_cycles"), res.cycles as f64);
        report.metric(format!("{label}_cluster_dma_bytes"), res.cluster_dma_bytes as f64);
    }
    println!(
        "\nthe pipelined variant moves inter-cluster traffic at the lowest level \
         (cf. paper: \"data ... is mainly transferred through the L1 networks\")"
    );

    section("engine throughput: activity-tracked vs full-scan (same workload)");
    // Warm up both paths once, then measure.
    let (event_res, event_s) = conv_run(false, ConvVariant::Stacked, budget);
    let (scan_res, scan_s) = conv_run(true, ConvVariant::Stacked, budget);
    assert!(event_res.finished && scan_res.finished);
    assert_eq!(
        (event_res.cycles, event_res.cluster_dma_bytes, &event_res.level_bytes),
        (scan_res.cycles, scan_res.cluster_dma_bytes, &scan_res.level_bytes),
        "sleep/wake must be simulation-invisible"
    );
    let event_cps = event_res.cycles as f64 / event_s;
    let scan_cps = scan_res.cycles as f64 / scan_s;
    let speedup = event_cps / scan_cps;
    println!(
        "full-scan engine:        {:>10.0} cycles/s  ({:.2}s wall, {} cycles)",
        scan_cps, scan_s, scan_res.cycles
    );
    println!(
        "activity-tracked engine: {:>10.0} cycles/s  ({:.2}s wall, {} cycles)",
        event_cps, event_s, event_res.cycles
    );
    println!("speedup: {speedup:.2}x (acceptance target: >= 2x)");
    report.metric("full_scan_cycles_per_sec", scan_cps);
    report.metric("event_cycles_per_sec", event_cps);
    report.metric("speedup", speedup);

    section("sharded engine: persistent pool + weighted placement (xsection load)");
    // CI sets NOC_BENCH_THREADS=4, so the smoke artifact always carries
    // the {1, 4}-thread pair and the parallel_efficiency trend metric.
    // Values below 2 fall back to 4: against the built-in 1-thread run
    // they would make the fingerprint assert vacuous and the efficiency
    // a noise ratio of two identical measurements.
    let shard_threads = std::env::var("NOC_BENCH_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 2)
        .unwrap_or(4);
    let window = iters(100_000, 10_000);
    let (fp1, wall1) = sharded_xsection(1, window);
    let (fp_n, wall_n) = sharded_xsection(shard_threads, window);
    assert_eq!(fp1, fp_n, "sharded runs must be bit-identical across thread counts");
    let sharded_cps = window as f64 / wall_n;
    let sharded_cps_1t = window as f64 / wall1;
    // Cycles/sec at N threads over N x the 1-thread rate: 1.0 = linear
    // scaling. Same-workload wall-clock ratio, so runner speed cancels
    // out (runner *noise* does not — see the trend-check threshold).
    let parallel_efficiency = sharded_cps / (shard_threads as f64 * sharded_cps_1t);
    println!(
        "sharded engine ({shard_threads} threads): {:>10.0} cycles/s  \
         ({:.2}s wall; 1-thread {:.0} cycles/s, {:.2}s; {} cycles)",
        sharded_cps, wall_n, sharded_cps_1t, wall1, window
    );
    println!(
        "parallel efficiency: {:.2} (cycles/s at {shard_threads} threads / \
         {shard_threads} x 1-thread)",
        parallel_efficiency
    );
    report.metric("sharded_cycles_per_sec", sharded_cps);
    report.metric("sharded_cycles_per_sec_1t", sharded_cps_1t);
    report.metric("sharded_threads", shard_threads as f64);
    report.metric("parallel_efficiency", parallel_efficiency);

    // Relay sleep: an idle sharded chiplet must be fully asleep between
    // exchanges — the cut relays were the last permanently-awake
    // components. Simulated state, not wall clock: deterministic.
    let idle_awake = {
        let engine = EngineOpts::sharded(2, 16);
        let cfg = ChipletCfg { fanout: bench_fanout(), engine, ..ChipletCfg::full() };
        let mut ch = Chiplet::new(cfg);
        ch.run(256);
        ch.awake_components()
    };
    println!("idle sharded chiplet awake components: {idle_awake}");
    report.metric("sharded_idle_awake_components", idle_awake as f64);
    assert_eq!(idle_awake, 0, "cut relays must sleep on an idle fabric");
    // Wall-clock assertions are unreliable on noisy shared CI runners with
    // sub-second quick-mode runs, so only enforce the floor in full mode;
    // the smoke job still records the metric in BENCH_tab2_manticore.json.
    if !quick() {
        assert!(
            speedup > 1.0,
            "activity tracking must not be slower than the full scan ({speedup:.2}x)"
        );
    }
    report.finish();
}
