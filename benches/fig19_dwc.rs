//! Fig. 19: data width converters — (a) downsizer 64→{8..32} and upsizer
//! 64→{128..512}, (b) upsizer with 1..8 read upsizers, plus a simulated
//! validation: the upsizer reshapes bursts so the wide side carries the
//! same bytes in proportionally fewer beats.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{iters, section, Report};
use noc::noc::upsizer::Upsizer;
use noc::protocol::payload::{Bytes, Cmd, RBeat, Resp};
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::Component;

/// Stream reads through an upsizer; returns (narrow beats, wide beats).
fn sim_upsize_ratio(dw: usize, n_txns: u64) -> (u64, u64) {
    let (up, up_s) = bundle("up", BundleCfg::new(64, 4));
    let (down_m, down_s) = bundle("down", BundleCfg::new(dw, 4));
    let mut uz = Upsizer::new("uz", up_s, down_m, 2);
    let ratio = dw / 64;
    let mut issued = 0u64;
    let mut narrow = 0u64;
    let mut wide = 0u64;
    let mut done = 0u64;
    let mut cy = 0u64;
    let mut pending: std::collections::VecDeque<RBeat> = Default::default();
    while done < n_txns && cy < 200_000 {
        cy += 1;
        up.set_now(cy);
        if issued < n_txns && up.ar.can_push() {
            // Aligned burst exactly `ratio` narrow beats long = 1 wide beat.
            let mut c = Cmd::new(0, (issued * dw as u64) % 0x10000, (ratio - 1) as u8, 3);
            c.tag = issued;
            up.ar.push(c);
            issued += 1;
        }
        down_s.set_now(cy);
        uz.tick(cy);
        if down_s.ar.can_pop() {
            let c = down_s.ar.pop();
            for i in 0..c.beats() {
                pending.push_back(RBeat {
                    id: c.id,
                    data: Bytes::zeroed(dw / 8),
                    resp: Resp::Okay,
                    last: i == c.beats() - 1,
                    tag: c.tag,
                });
            }
        }
        if !pending.is_empty() && down_s.r.can_push() {
            down_s.r.push(pending.pop_front().unwrap());
            wide += 1;
        }
        if up.r.can_pop() {
            let r = up.r.pop();
            narrow += 1;
            if r.last {
                done += 1;
            }
        }
    }
    assert_eq!(done, n_txns, "upsizer traffic must complete");
    (narrow, wide)
}

fn main() {
    let mut report = Report::new("fig19_dwc");
    let n_txns = iters(500, 100);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 19")) {
        println!("{}", s.render());
    }
    println!("paper: downsizer 390->365 ps / 23->25 kGE; upsizer 380->405 ps / 27->35 kGE; R=1..8: 380->485 ps / 27->59 kGE\n");

    section("simulated upsizer burst reshaping (narrow beats : wide beats)");
    for dw in [128usize, 256, 512] {
        let (narrow, wide) = sim_upsize_ratio(dw, n_txns);
        report.metric(format!("narrow_beats_dw{dw}"), narrow as f64);
        report.metric(format!("wide_beats_dw{dw}"), wide as f64);
        let ratio = narrow as f64 / wide as f64;
        let at = area_timing(Module::Upsizer { dn: 64, dw, r: 2 });
        println!(
            "64 -> {dw}: {narrow} narrow / {wide} wide = {ratio:.2}x (expect {}x)  (model {:.0} ps, {:.1} kGE)",
            dw / 64,
            at.cp_ps,
            at.kge
        );
        assert!((ratio - (dw / 64) as f64).abs() < 0.01, "reshape ratio off");
    }

    println!("\nread-upsizer scaling (model):");
    for r in [1usize, 2, 4, 8] {
        let at = area_timing(Module::Upsizer { dn: 64, dw: 128, r });
        println!("  R={r}: {:.0} ps, {:.1} kGE", at.cp_ps, at.kge);
    }
    report.finish();
}
