//! Fig. 18: ID serializer — (a) U_M = 1..32 master-port IDs at T = 8,
//! (b) T = 1..32 at U_M = 4, plus the paper's §3.3.2 comparison (128 txns
//! at U_M=4/T=32 vs U_M=16/T=8) and a simulated check that serialization
//! preserves per-f(ID) ordering while different FIFOs stay concurrent.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{iters, section, Report};
use noc::noc::id_serialize::IdSerialize;
use noc::protocol::payload::{Bytes, Cmd, RBeat, Resp};
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::Component;

fn sim_serializer(u_m: usize, t: usize, n: u64) -> f64 {
    let (up, up_s) = bundle("up", BundleCfg::new(64, 8));
    let (down_m, down_s) = bundle("down", BundleCfg::new(64, 6));
    let mut ser = IdSerialize::new("ser", up_s, down_m, u_m, t);
    let mut issued = 0u64;
    let mut done = 0u64;
    let mut cy = 0u64;
    while done < n && cy < 100_000 {
        cy += 1;
        up.set_now(cy);
        if issued < n && up.ar.can_push() {
            let mut c = Cmd::new((issued % 64) as u32, (issued % 8) << 6, 0, 3);
            c.tag = issued;
            up.ar.push(c);
            issued += 1;
        }
        down_s.set_now(cy);
        ser.tick(cy);
        if down_s.ar.can_pop() {
            let c = down_s.ar.pop();
            assert!((c.id as usize) < u_m, "output IDs within U_M");
            down_s.r.push(RBeat { id: c.id, data: Bytes::zeroed(8), resp: Resp::Okay, last: true, tag: c.tag });
        }
        if up.r.can_pop() {
            up.r.pop();
            done += 1;
        }
    }
    assert_eq!(done, n);
    done as f64 / cy as f64
}

fn main() {
    let mut report = Report::new("fig18_serializer");
    let total = iters(2000, 400);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 18")) {
        println!("{}", s.render());
    }
    println!("paper endpoints: (a) 195->410 ps, 2->109 kGE; (b) 245->280 ps, 15->51 kGE\n");

    // §3.3.2: 128 concurrent txns at U_M=4/T=32 is cheaper than U_M=16/T=8.
    let a = area_timing(Module::IdSerialize { um: 16, t: 8 });
    let b = area_timing(Module::IdSerialize { um: 4, t: 32 });
    println!(
        "128-txn configs: U_M=16/T=8 {:.1} kGE vs U_M=4/T=32 {:.1} kGE -> {:.2}x (paper: 1.28x)\n",
        a.kge,
        b.kge,
        a.kge / b.kge
    );

    section("simulated serializer throughput (64 input IDs folded to U_M)");
    for (um, t) in [(1usize, 8usize), (4, 8), (16, 8), (32, 8), (4, 32)] {
        let tput = sim_serializer(um, t, total);
        report.metric(format!("txn_per_cycle_um{um}_t{t}"), tput);
        println!("U_M={um:<3} T={t:<3} {tput:.3} txns/cycle");
        assert!(tput > 0.4, "serializer throughput too low");
    }
    report.finish();
}
