//! Fault injection & recovery costs (`fault`, `noc::d2d`, `noc::dma`).
//!
//! Headline metric: `faulty_link_goodput_frac` — the fraction of a
//! clean link's hierarchical all-reduce goodput a 4-chiplet pod retains
//! when every D2D link corrupts data beats at a 1e-3 per-beat rate and
//! the CRC + replay layer recovers them — recorded in `BENCH_fault.json`
//! and tracked by `scripts/check_bench_trend.py`. The bench hard-asserts
//! the acceptance gate (>= 0.70) and that the result stays element-wise
//! exact; injection is seeded and rolled only on beat events, so every
//! number here is deterministic.
//!
//! Also measured: the same fraction at an aggressive 1e-2 rate (the
//! knee of the replay protocol), and `dma_retry_overhead_frac` — the
//! cycle cost of riding out a transient SLVERR window through the DMA's
//! bounded-backoff retry path, relative to a clean copy.

use noc::bench_harness::{quick, section, Report};
use noc::fault::{BeatFaultKind, FaultPlan, SlvErrWindow};
use noc::manticore::chiplet::ChipletCfg;
use noc::manticore::pod::{run_pod_collective, Pod, PodCfg, PodCollectiveResult};
use noc::noc::d2d::D2DCfg;
use noc::noc::dma::{Dma, DmaRetryCfg, TransferReq};
use noc::noc::mem_duplex::{BankArray, MemDuplex};
use noc::protocol::{bundle, BundleCfg, Resp};
use noc::sim::{Component, EngineOpts};

const BUDGET: u64 = 50_000_000;

fn die() -> ChipletCfg {
    let fanout = if quick() { vec![2] } else { vec![2, 2] };
    let engine = EngineOpts::sharded(4, 8);
    ChipletCfg { fanout, engine, ..ChipletCfg::full() }
}

fn payload() -> u64 {
    if quick() {
        16 * 1024
    } else {
        32 * 1024
    }
}

/// One 4-chiplet hierarchical all-reduce; returns the result plus the
/// pod-wide (retransmits, dropped) counters.
fn run_pod(fault: Option<FaultPlan>, label: &str) -> (PodCollectiveResult, u64, u64) {
    let mut pod = Pod::new(PodCfg {
        n_chiplets: 4,
        die: die(),
        d2d: D2DCfg::default(),
        fault,
        watchdog: 0,
    });
    let r = run_pod_collective(&mut pod, payload(), BUDGET, true).expect("pod collective builds");
    assert!(r.finished, "{label}: all-reduce must finish");
    assert!(r.correct, "{label}: all-reduce must stay element-wise exact");
    let (mut retr, mut drops) = (0u64, 0u64);
    for d in &pod.dies {
        for (_, c) in &d.d2d {
            retr += c.retransmits();
            drops += c.dropped();
        }
    }
    (r, retr, drops)
}

/// A 4 KiB DMA copy against a duplex memory controller; returns the
/// completion cycle, retry count, and merged response. `window` arms a
/// transient SLVERR on the destination range that the retry path must
/// ride out.
fn dma_copy(window: Option<SlvErrWindow>) -> (u64, u64, Resp) {
    let cfg = BundleCfg::new(64, 4);
    let (m, s) = bundle("bench.dma", cfg);
    let banks = BankArray::new(0, 1 << 20, 4, 8, 1);
    let mut dma =
        Dma::new("bench.dma", m).with_retry(DmaRetryCfg { max_retries: 16, backoff_cycles: 64 });
    let mut mem = MemDuplex::new("bench.mem", s, banks);
    if let Some(w) = window {
        mem.set_fault_window(w);
    }
    let data: Vec<u8> = (0..4096).map(|i| (i * 7 % 251) as u8).collect();
    mem.banks.borrow_mut().poke(0x1000, &data);
    let h = dma.submit(TransferReq::OneD { src: 0x1000, dst: 0x40_000, len: 4096 });
    let mut cy = 0u64;
    let resp = loop {
        cy += 1;
        assert!(cy < 1_000_000, "bench copy must complete");
        dma.tick(cy);
        mem.tick(cy);
        if let Some(r) = dma.take_completed_with_resp(h, cy + 2) {
            break r;
        }
    };
    assert_eq!(mem.banks.borrow().peek_vec(0x40_000, 4096), data, "copy must be byte-exact");
    (cy, dma.retries, resp)
}

fn main() {
    let mut report = Report::new("fault");
    let bytes = payload();

    section(&format!("4-chiplet pod, {bytes} B hierarchical all-reduce, default D2D link"));
    let (clean, _, _) = run_pod(None, "clean");
    println!(
        "{:<34} {:>9} cycles  {:>7.2} B/cycle",
        "clean link", clean.cycles, clean.bytes_per_cycle
    );
    for (label, rate, key, headline) in [
        ("1e-3 corrupt (headline)", 1e-3, "faulty_link_goodput_frac", true),
        ("1e-2 corrupt (stress)", 1e-2, "faulty_link_goodput_frac_1e2", false),
    ] {
        let plan = FaultPlan::beat_errors(1, rate, BeatFaultKind::Corrupt);
        let (r, retr, drops) = run_pod(Some(plan), label);
        let frac = r.bytes_per_cycle / clean.bytes_per_cycle;
        println!(
            "{label:<34} {:>9} cycles  {:>7.2} B/cycle  ({:.0}% of clean, \
             {retr} replays, {drops} drops)",
            r.cycles,
            r.bytes_per_cycle,
            100.0 * frac
        );
        report.metric(key, frac);
        if headline {
            assert!(
                frac >= 0.70,
                "acceptance gate: goodput at 1e-3 must stay >= 70% of clean, got {:.0}%",
                100.0 * frac
            );
            report.metric("faulty_link_retransmits", retr as f64);
        }
    }

    section("transient SLVERR window ridden out by DMA retry (4 KiB copy)");
    let (clean_cy, r0, resp0) = dma_copy(None);
    assert_eq!((r0, resp0), (0, Resp::Okay), "clean copy must not retry");
    let (faulty_cy, retries, resp) = dma_copy(Some(SlvErrWindow {
        base: 0x40_000,
        len: 4096,
        until: Some(clean_cy * 2),
    }));
    assert_eq!(resp, Resp::Okay, "retry must eventually succeed past the window");
    assert!(retries >= 1, "the window must force at least one retry");
    let overhead = (faulty_cy as f64 - clean_cy as f64) / clean_cy as f64;
    println!(
        "clean {clean_cy} cycles; window until {} -> {faulty_cy} cycles, {retries} retries \
         ({:+.0}% overhead)",
        clean_cy * 2,
        100.0 * overhead
    );
    report.metric("dma_retry_overhead_frac", overhead);
    report.metric("dma_retries", retries as f64);

    report.finish();
}
