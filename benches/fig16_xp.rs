//! Fig. 16: crosspoint (pipelined, with ID remappers; isomorphous ports) —
//! (a) 2..8 master ports, (b) 2..8 ID bits, plus simulated validation that
//! ports stay isomorphous and traffic completes under load.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{iters, section, Report};
use noc::noc::addr_decode::{AddrMap, AddrRule, DefaultPort};
use noc::noc::crosspoint::{Crosspoint, CrosspointCfg};
use noc::protocol::payload::{Bytes, Cmd, RBeat, Resp};
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::{Component, SplitMix64};

fn sim_crosspoint(ports: usize, total: u64) -> f64 {
    let cfg = BundleCfg::new(64, 4);
    let map = AddrMap::new(
        (0..ports).map(|i| AddrRule::new(i as u64 * 0x1000, (i as u64 + 1) * 0x1000, i)).collect(),
        DefaultPort::Error,
    );
    let mut ups = Vec::new();
    let mut xs = Vec::new();
    let mut xm = Vec::new();
    let mut downs = Vec::new();
    for i in 0..ports {
        let (m, s) = bundle(&format!("u{i}"), cfg);
        ups.push(m);
        xs.push(s);
        let (m2, s2) = bundle(&format!("d{i}"), cfg);
        xm.push(m2);
        downs.push(s2);
    }
    let mut xp = Crosspoint::new(
        "xp",
        xs,
        xm,
        CrosspointCfg::full(cfg, map, ports, ports),
    );
    let mut rng = SplitMix64::new(1);
    let mut completed = 0u64;
    let mut issued = 0u64;
    let mut cy = 0u64;
    while completed < total && cy < 200_000 {
        cy += 1;
        for u in &ups {
            u.set_now(cy);
            if issued < total && u.ar.can_push() {
                let mut c = Cmd::new(rng.below(16) as u32, rng.below((ports as u64) * 0x1000) & !7, 0, 3);
                c.tag = issued;
                u.ar.push(c);
                issued += 1;
            }
        }
        for d in &downs {
            d.set_now(cy);
        }
        xp.tick(cy);
        for d in &downs {
            if d.ar.can_pop() {
                let c = d.ar.pop();
                assert!(c.id < 16, "isomorphous ports: ID stays within 4 bits");
                d.r.push(RBeat { id: c.id, data: Bytes::zeroed(8), resp: Resp::Okay, last: true, tag: c.tag });
            }
        }
        for u in &ups {
            if u.r.can_pop() {
                u.r.pop();
                completed += 1;
            }
        }
    }
    assert_eq!(completed, total, "crosspoint must complete all traffic");
    completed as f64 / cy as f64
}

fn main() {
    let mut report = Report::new("fig16_xp");
    let total = iters(4000, 600);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 16")) {
        println!("{}", s.render());
    }
    println!("paper endpoints: (a) 610->630 ps, 243->587 kGE; (b) 290->800 ps, 127->1181 kGE\n");

    section("simulated NxN crosspoint, uniform random, 16 unique IDs");
    for p in [2usize, 4, 8] {
        let tput = sim_crosspoint(p, total);
        report.metric(format!("txn_per_cycle_p{p}"), tput);
        let at = area_timing(Module::Crosspoint { s: p, m: p, i: 4 });
        println!(
            "{p}x{p}: {tput:.3} txns/cycle  (model {:.0} ps, {:.0} kGE)",
            at.cp_ps, at.kge
        );
    }
    report.finish();
}
