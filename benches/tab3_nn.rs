//! Table 3: Manticore NN-layer performance — the analytical reproduction
//! at paper scale plus the simulated scaled-down rows (16-cluster chiplet,
//! CONV_SMALL workload), reporting the same columns the paper does.

use noc::bench_harness::{iters, quick, section, Report};
use noc::manticore::chiplet::{Chiplet, ChipletCfg};
use noc::manticore::perf::{render_table3, table3, Machine};
use noc::manticore::workload::{
    conv_scripts, fc_scripts, run_scripts, ConvVariant, CLUSTER_FLOPS_PER_CYCLE, CONV_PAPER,
    CONV_SMALL,
};

fn main() {
    let mut report = Report::new("tab3_nn");
    let budget = iters(50_000_000, 5_000_000);
    // Analytical table at paper scale.
    let rows = table3(&Machine::manticore(), CONV_PAPER, 8, 32);
    println!("{}", render_table3(&rows));
    println!(
        "paper values: base OI 2.2 / 262 GB/s / 571 Gdpflop/s; stacked OI 15.9 / 98 / 1638;\n\
         pipe'd HBM 6, L2 25, L1 98 / 1638; FC OI 7.9 / 222 / 1638\n"
    );

    // Simulated scaled-down measurement.
    section("simulated (scaled-down chiplet + conv layer)");
    let fanout = if quick() { vec![2, 2] } else { vec![4, 4] };
    let conv = if quick() {
        noc::manticore::workload::ConvCfg { wi: 8, di: 16, k: 16, f: 3, p: 1, s: 1 }
    } else {
        CONV_SMALL
    };
    let cfg = ChipletCfg { fanout, ..ChipletCfg::full() };
    let n = cfg.n_clusters();
    let compute_bound = n as f64 * CLUSTER_FLOPS_PER_CYCLE;
    for (label, variant, stack) in [
        ("conv base", ConvVariant::Baseline, 1usize),
        ("conv stacked", ConvVariant::Stacked, 8),
        ("conv pipe'd", ConvVariant::Pipelined, 8),
    ] {
        let mut ch = Chiplet::new(cfg.clone());
        let res = run_scripts(&mut ch, conv_scripts(conv, variant, n, stack), budget);
        assert!(res.finished);
        let gflops = conv.flops() as f64 / res.cycles as f64;
        report.metric(format!("{}_gflops", label.replace([' ', '\''], "_")), gflops);
        println!(
            "{label:<14} HBM {:>6.1} GB/s   perf {:>6.1} Gdpflop/s ({:>3.0}% of compute bound)",
            res.gbps(res.hbm_bytes),
            gflops,
            100.0 * gflops / compute_bound
        );
    }
    {
        let mut ch = Chiplet::new(cfg);
        let res = run_scripts(&mut ch, fc_scripts(8, 16, 32, 32, n), budget);
        assert!(res.finished);
        report.metric("fc_hbm_gbps", res.gbps(res.hbm_bytes));
        println!("{:<14} HBM {:>6.1} GB/s", "fully conn.", res.gbps(res.hbm_bytes));
    }
    println!("\nshape check: baseline is HBM-bound; stacked/pipelined approach the compute bound;\npipelined slashes HBM traffic at equal performance — as in Table 3.");
    report.finish();
}
