//! Fig. 14: network demultiplexer — (a) 2..32 master ports at 6 ID bits,
//! (b) 2..8 ID bits at 4 master ports (exponential area blowup), plus a
//! cycle-level validation: same-ID traffic to one target sustains full
//! rate; the counters only stall target *changes*.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{iters, section, Report};
use noc::noc::demux::Demux;
use noc::protocol::payload::{Bytes, Cmd, RBeat, Resp};
use noc::protocol::port::{bundle, BundleCfg};
use noc::sim::Component;

fn sim_demux_throughput(m: usize, spread_ids: bool, cycles: u64) -> f64 {
    let cfg = BundleCfg::new(64, 6);
    let (up, up_s) = bundle("up", cfg);
    let mut masters = Vec::new();
    let mut downs = Vec::new();
    for i in 0..m {
        let (mm, ss) = bundle(&format!("d{i}"), cfg);
        masters.push(mm);
        downs.push(ss);
    }
    let mc = m;
    let mut demux =
        Demux::new_symmetric("demux", up_s, masters, move |c: &Cmd| (c.addr as usize >> 6) % mc)
            .with_max_txns_per_id(8);
    let mut done = 0u64;
    let mut i = 0u64;
    for cy in 1..=cycles {
        up.set_now(cy);
        if up.ar.can_push() {
            let id = if spread_ids { (i % 64) as u32 } else { 0 };
            let mut c = Cmd::new(id, (i % m as u64) << 6, 0, 3);
            c.tag = i;
            up.ar.push(c);
            i += 1;
        }
        for d in &downs {
            d.set_now(cy);
        }
        demux.tick(cy);
        for d in &downs {
            if d.ar.can_pop() {
                let c = d.ar.pop();
                d.r.push(RBeat { id: c.id, data: Bytes::zeroed(8), resp: Resp::Okay, last: true, tag: c.tag });
            }
        }
        if up.r.can_pop() {
            up.r.pop();
            done += 1;
        }
    }
    done as f64 / cycles as f64
}

fn main() {
    let mut report = Report::new("fig14_demux");
    let cycles = iters(20_000, 2_000);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 14")) {
        println!("{}", s.render());
    }
    println!("paper endpoints: (a) 330->430 ps, 22->38 kGE; (b) 250->400 ps, 5->95 kGE\n");

    section("simulated demux: round-robin targets, spread vs single ID");
    for m in [2usize, 4, 8, 16, 32] {
        let spread = sim_demux_throughput(m, true, cycles);
        let single = sim_demux_throughput(m, false, cycles);
        report.metric(format!("spread_txn_per_cycle_m{m}"), spread);
        report.metric(format!("single_txn_per_cycle_m{m}"), single);
        let at = area_timing(Module::Demux { m, i: 6 });
        println!(
            "M={m:<3} spread-IDs {spread:.3} txn/cy, single-ID {single:.3} txn/cy  (model {:.0} ps, {:.1} kGE)",
            at.cp_ps, at.kge
        );
        // Spread IDs: different IDs may target different ports concurrently.
        assert!(spread > 0.8, "spread-ID throughput too low: {spread}");
        // Single ID round-robining across targets must serialize (the
        // same-target ordering rule) — visibly below the spread case.
        assert!(single < spread + 0.05);
    }
    println!("\nexponential ID-width cost (model): ");
    for i in [2usize, 4, 6, 8] {
        let at = area_timing(Module::Demux { m: 4, i });
        println!("  I={i}: {:.1} kGE, {:.0} ps", at.kge, at.cp_ps);
    }
    report.finish();
}
