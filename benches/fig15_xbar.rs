//! Fig. 15: crossbar (fully connected, S=4) — (a) 2..8 master ports,
//! (b) 2..8 ID bits, plus simulated end-to-end throughput of a 4×M
//! crossbar under uniform random traffic.

use noc::area::{all_figures, area_timing, Module};
use noc::bench_harness::{bench, iters, section, Report};
use noc::coordinator::{SimCfg, System};

fn xbar_cfg_toml(masters: usize, total: u64) -> String {
    let mut s = String::from("[sim]\ncycles = 200000\ndata_bits = 64\nid_bits = 6\n");
    for i in 0..4 {
        s.push_str(&format!(
            "[[master]]\nname = \"g{i}\"\nbase = 0x0\nspan = {}\ntotal = {total}\nmax_outstanding = 8\nids = 8\n",
            masters * 0x1_0000
        ));
    }
    for m in 0..masters {
        s.push_str(&format!(
            "[[slave]]\nname = \"s{m}\"\nkind = \"perfect\"\nlatency = 2\nbase = {}\nsize = 0x1_0000\n",
            m * 0x1_0000
        ));
    }
    s
}

fn sim_xbar(masters: usize, total: u64) -> (f64, u64) {
    let cfg = SimCfg::from_str_toml(&xbar_cfg_toml(masters, total)).unwrap();
    let mut sys = System::build(&cfg).unwrap();
    let done = sys.run(cfg.cycles);
    assert!(done, "crossbar traffic must complete");
    assert!(sys.check_protocol().is_empty());
    let txns: u64 = sys.gens.iter().map(|g| g.borrow().stats.completed).sum();
    (txns as f64 / sys.cycles as f64, sys.cycles)
}

fn main() {
    let mut report = Report::new("fig15_xbar");
    let total = iters(2000, 300);
    for s in all_figures().iter().filter(|s| s.figure.starts_with("Fig 15")) {
        println!("{}", s.render());
    }
    println!("paper endpoints: (a) 400->450 ps, 111->156 kGE; (b) 340->460 ps, 42->390 kGE\n");

    section("simulated 4xM crossbar under uniform random traffic");
    for m in [2usize, 4, 6, 8] {
        let (tput, cycles) = sim_xbar(m, total);
        report.metric(format!("txn_per_cycle_m{m}"), tput);
        report.metric(format!("cycles_m{m}"), cycles as f64);
        let at = area_timing(Module::Xbar { s: 4, m, i: 6 });
        println!(
            "M={m}: {tput:.3} txns/cycle over {cycles} cycles  (model {:.0} ps, {:.0} kGE, {:.2} GHz)",
            at.cp_ps,
            at.kge,
            at.fmax_ghz()
        );
        assert!(tput > 0.5, "4x{m} crossbar too slow: {tput}");
    }

    section("build+run wall time");
    let t = report.timing(bench(&format!("4x4 xbar, {} txns", 4 * total), 3, Some(4 * total), || {
        sim_xbar(4, total);
    }));
    println!("{}", t.row());
    report.finish();
}
