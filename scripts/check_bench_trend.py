#!/usr/bin/env python3
"""Bench trend check: compare fresh BENCH_*.json files against the
previous CI run's archived artifact and fail on >20% regression of the
tracked throughput metrics (see ROADMAP "Bench trend dashboards").

Usage: check_bench_trend.py <prev-dir> <new-dir>

Exits 0 (with a note) when no previous artifact exists — the first run
on a branch has no baseline. Exits 1 when any tracked metric regressed
by more than the threshold.
"""

import json
import sys
from pathlib import Path

# (file name, metric key[, threshold]) tuples; all tracked metrics are
# higher-is-better throughput/speedup numbers. A missing threshold uses
# the default below.
TRACKED = [
    ("BENCH_tab2_manticore.json", "event_cycles_per_sec"),
    ("BENCH_tab2_manticore.json", "speedup"),
    ("BENCH_tab2_manticore.json", "sharded_cycles_per_sec"),
    # N-thread cycles/sec over N x 1-thread cycles/sec: the headline of
    # the lock-free/pool/weighted sharded engine. A wall-clock *ratio*
    # of two same-workload runs, so runner speed cancels — but runner
    # *noise* does not, and the quick-mode runs are sub-second, so this
    # metric gets a looser gate than the default: it still hard-fails on
    # a real scaling collapse (e.g. a reintroduced lock) while tolerating
    # shared-runner jitter. Loosen further rather than untracking.
    ("BENCH_tab2_manticore.json", "parallel_efficiency", 0.35),
    ("BENCH_coordinator_engine.json", "event_cycles_per_sec"),
    ("BENCH_coordinator_engine.json", "speedup"),
    # Aggregate throughput over the examples/topologies/ presets: the
    # grammar-built systems (converter trunks included). Quick-mode runs
    # are sub-second wall clocks on shared runners, so this gets the
    # looser gate (cf. parallel_efficiency above).
    ("BENCH_coordinator_engine.json", "topology_presets_cycles_per_sec", 0.35),
    # Simulated (deterministic) collective bandwidth: regressions here are
    # real scheduling/fabric changes, not runner noise.
    ("BENCH_collective.json", "allreduce_bytes_per_cycle"),
]
THRESHOLD = 0.20


_METRICS_CACHE = {}


def metrics(path: Path):
    """Parse a bench artifact; None if it is truncated/corrupt/unreadable.

    A damaged *previous* artifact must degrade to a skip (the baseline is
    best-effort), not crash the check — that includes files that are valid
    JSON but not the expected object shape (e.g. a truncated rewrite that
    left just "null"). Results are cached so a file tracked under several
    keys is parsed (and reported unreadable) once.
    """
    if path in _METRICS_CACHE:
        return _METRICS_CACHE[path]
    try:
        with open(path) as f:
            doc = json.load(f)
        if not isinstance(doc, dict):
            raise ValueError(f"expected a JSON object, got {type(doc).__name__}")
        result = doc.get("metrics", {})
        if not isinstance(result, dict):
            raise ValueError(f"'metrics' is {type(result).__name__}, not an object")
    except (json.JSONDecodeError, OSError, ValueError) as e:
        print(f"{path}: unreadable ({e})")
        result = None
    _METRICS_CACHE[path] = result
    return result


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    prev_dir, new_dir = Path(argv[1]), Path(argv[2])
    if not prev_dir.is_dir():
        print(f"no previous bench artifact at {prev_dir}; skipping trend check")
        return 0
    failures = []
    for entry in TRACKED:
        fname, key = entry[0], entry[1]
        threshold = entry[2] if len(entry) > 2 else THRESHOLD
        prev_file, new_file = prev_dir / fname, new_dir / fname
        if not prev_file.exists():
            print(f"{fname}:{key}: no previous copy, skipping")
            continue
        if not new_file.exists():
            failures.append(f"{fname}: missing from the fresh results")
            continue
        prev_metrics = metrics(prev_file)
        if prev_metrics is None:
            print(f"{fname}:{key}: unreadable previous artifact, skipping")
            continue
        new_metrics = metrics(new_file)
        if new_metrics is None:
            msg = f"{fname}: fresh results are unreadable"
            if msg not in failures:
                failures.append(msg)
            continue
        prev = prev_metrics.get(key)
        new = new_metrics.get(key)
        if prev is None or prev <= 0:
            print(f"{fname}:{key}: no previous value, skipping")
            continue
        if new is None:
            failures.append(f"{fname}:{key}: metric missing from fresh results")
            continue
        if new <= 0:
            # A throughput of zero (or less) is a broken measurement, not
            # a regression ratio worth computing.
            failures.append(f"{fname}:{key}: fresh value {new!r} is not positive")
            continue
        change = (new - prev) / prev
        regressed = change < -threshold
        print(
            f"{fname}:{key}: {prev:.4g} -> {new:.4g} "
            f"({change:+.1%}, gate {threshold:.0%}) {'REGRESSION' if regressed else 'ok'}"
        )
        if regressed:
            failures.append(
                f"{fname}:{key} regressed {change:+.1%} ({prev:.4g} -> {new:.4g})"
            )
    if failures:
        print("\nbench trend check FAILED (regression past gate):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench trend check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
