#!/usr/bin/env python3
"""Bench trend check: compare fresh BENCH_*.json files against the
previous CI run's archived artifact and fail on >20% regression of the
tracked throughput metrics (see ROADMAP "Bench trend dashboards").

Usage: check_bench_trend.py <prev-dir> <new-dir>

Exits 0 (with a note) when no previous artifact exists — the first run
on a branch has no baseline. Exits 1 when any tracked metric regressed
by more than the threshold.
"""

import json
import sys
from pathlib import Path

# (file name, metric key) pairs; all tracked metrics are
# higher-is-better throughput/speedup numbers.
TRACKED = [
    ("BENCH_tab2_manticore.json", "event_cycles_per_sec"),
    ("BENCH_tab2_manticore.json", "speedup"),
    ("BENCH_coordinator_engine.json", "event_cycles_per_sec"),
    ("BENCH_coordinator_engine.json", "speedup"),
]
THRESHOLD = 0.20


def metrics(path: Path):
    with open(path) as f:
        return json.load(f).get("metrics", {})


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    prev_dir, new_dir = Path(argv[1]), Path(argv[2])
    if not prev_dir.is_dir():
        print(f"no previous bench artifact at {prev_dir}; skipping trend check")
        return 0
    failures = []
    for fname, key in TRACKED:
        prev_file, new_file = prev_dir / fname, new_dir / fname
        if not prev_file.exists():
            print(f"{fname}:{key}: no previous copy, skipping")
            continue
        if not new_file.exists():
            failures.append(f"{fname}: missing from the fresh results")
            continue
        prev = metrics(prev_file).get(key)
        new = metrics(new_file).get(key)
        if prev is None or prev <= 0:
            print(f"{fname}:{key}: no previous value, skipping")
            continue
        if new is None:
            failures.append(f"{fname}:{key}: metric missing from fresh results")
            continue
        change = (new - prev) / prev
        regressed = change < -THRESHOLD
        print(
            f"{fname}:{key}: {prev:.4g} -> {new:.4g} "
            f"({change:+.1%}) {'REGRESSION' if regressed else 'ok'}"
        )
        if regressed:
            failures.append(
                f"{fname}:{key} regressed {change:+.1%} ({prev:.4g} -> {new:.4g})"
            )
    if failures:
        print("\nbench trend check FAILED (>20% regression):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench trend check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
