#!/usr/bin/env python3
"""Bench trend check: compare fresh BENCH_*.json files against the
previous CI run's archived artifact and fail on regression of the
tracked metrics (see ROADMAP "Bench trend dashboards").

Usage: check_bench_trend.py <prev-dir> <new-dir>

Most tracked metrics are higher-is-better throughputs gated on relative
change (>20% drop fails, unless the entry carries a looser threshold).
Entries with mode="abs-increase" are lower-is-better fractions gated on
absolute growth instead (a ratio on a near-zero baseline is noise).
Entries with mode="drift" are direction-less deterministic values (the
telemetry energy metrics) gated on relative movement either way.
Entries with a "condition" key are only compared when that metric (e.g.
the sharded thread count) is identical in both artifacts — comparing an
8-thread efficiency against a 4-thread baseline would be meaningless.

Exits 0 (with a note) when no previous artifact exists — the first run
on a branch has no baseline. Exits 1 when any tracked metric regressed
past its gate, or when the fresh tab2 artifact was not produced at
>= MIN_SHARDED_THREADS worker threads (the scaling gate must actually
exercise scaling).
"""

import json
import sys
from pathlib import Path

# Tracked metrics. Keys: file, key, threshold (optional), mode
# (optional: "abs-increase"), condition (optional: metric key that must
# match between the two artifacts for the comparison to make sense).
TRACKED = [
    {"file": "BENCH_tab2_manticore.json", "key": "event_cycles_per_sec"},
    {"file": "BENCH_tab2_manticore.json", "key": "speedup"},
    {"file": "BENCH_tab2_manticore.json", "key": "sharded_cycles_per_sec"},
    # N-thread cycles/sec over N x 1-thread cycles/sec: the headline of
    # the lock-free/pool/weighted sharded engine. A wall-clock *ratio*
    # of two same-workload runs, so runner speed cancels — but runner
    # *noise* does not, and the quick-mode runs are sub-second, so this
    # metric gets a looser gate than the default: it still hard-fails on
    # a real scaling collapse (e.g. a reintroduced lock) while tolerating
    # shared-runner jitter. Loosen further rather than untracking. Only
    # comparable at an unchanged thread count.
    {
        "file": "BENCH_tab2_manticore.json",
        "key": "parallel_efficiency",
        "threshold": 0.35,
        "condition": "sharded_threads",
    },
    # Fraction of worker wall clock stalled at the epoch barrier or in
    # the exchange. Lower is better and legitimately near zero, so the
    # gate is absolute growth, not a ratio.
    {
        "file": "BENCH_tab2_manticore.json",
        "key": "exchange_stall_frac",
        "threshold": 0.15,
        "mode": "abs-increase",
        "condition": "sharded_threads",
    },
    # Wall-clock ratio of fixed vs adaptive epoch pacing over an idle
    # tail; same noise profile as parallel_efficiency.
    {
        "file": "BENCH_tab2_manticore.json",
        "key": "adaptive_epoch_speedup",
        "threshold": 0.35,
        "condition": "sharded_threads",
    },
    {"file": "BENCH_coordinator_engine.json", "key": "event_cycles_per_sec"},
    {"file": "BENCH_coordinator_engine.json", "key": "speedup"},
    # Aggregate throughput over the examples/topologies/ presets: the
    # grammar-built systems (converter trunks included). Quick-mode runs
    # are sub-second wall clocks on shared runners, so this gets the
    # looser gate (cf. parallel_efficiency above).
    {
        "file": "BENCH_coordinator_engine.json",
        "key": "topology_presets_cycles_per_sec",
        "threshold": 0.35,
    },
    # Simulated (deterministic) collective bandwidth: regressions here are
    # real scheduling/fabric changes, not runner noise.
    {"file": "BENCH_collective.json", "key": "allreduce_bytes_per_cycle"},
    # Pod-scale hierarchical all-reduce over constrained D2D links —
    # deterministic simulated throughput, same noise-free profile as the
    # single-die collective metric above. The bench itself additionally
    # asserts hierarchical >= flat-ring at 4 chiplets.
    {"file": "BENCH_multichip.json", "key": "d2d_allreduce_bytes_per_cycle"},
    {"file": "BENCH_multichip.json", "key": "hier_over_flat_speedup"},
    # Fault layer (PR 10): fraction of a clean link's all-reduce goodput
    # retained at a 1e-3 per-beat D2D error rate with CRC+replay armed —
    # deterministic simulated values (seeded injection), so any movement
    # is a real change in the replay protocol or the schedule. The bench
    # itself hard-asserts >= 0.70.
    {"file": "BENCH_fault.json", "key": "faulty_link_goodput_frac"},
    # Cycle overhead of riding out a transient SLVERR window via DMA
    # retry, relative to a clean copy. Lower is better and legitimately
    # small, so gate on absolute growth, not a ratio.
    {
        "file": "BENCH_fault.json",
        "key": "dma_retry_overhead_frac",
        "threshold": 0.50,
        "mode": "abs-increase",
    },
    # Telemetry energy accounting: deterministic simulated values (active
    # cycles x area-model power + per-byte link energy), so they move
    # only when the model or the schedule changes. Neither direction is
    # "better" — mode="drift" fails on a large swing either way, forcing
    # an intentional recalibration to show up in review instead of
    # sliding through silently.
    {
        "file": "BENCH_collective.json",
        "key": "allreduce_energy_pj",
        "threshold": 0.50,
        "mode": "drift",
    },
    {
        "file": "BENCH_collective.json",
        "key": "energy_per_byte_pj",
        "threshold": 0.50,
        "mode": "drift",
    },
    {
        "file": "BENCH_tab2_manticore.json",
        "key": "energy_per_inference_pj",
        "threshold": 0.50,
        "mode": "drift",
    },
]
THRESHOLD = 0.20

# Hard gate on the fresh artifact (no baseline needed): wall-clock cost
# of running with telemetry attached, as a fraction over the untraced
# run (best-of-3 each, measured by the tab2 bench). The layer's pitch is
# "attachable in CI by default", which only holds while this stays small.
MAX_TELEMETRY_OVERHEAD = 0.05

# The parallel_efficiency gate must be measured at real scale: fail if
# the fresh tab2 artifact ran its sharded section below this many worker
# threads (CI pins NOC_BENCH_THREADS=8).
MIN_SHARDED_THREADS = 8


_METRICS_CACHE = {}


def metrics(path: Path):
    """Parse a bench artifact; None if it is truncated/corrupt/unreadable.

    A damaged *previous* artifact must degrade to a skip (the baseline is
    best-effort), not crash the check — that includes files that are valid
    JSON but not the expected object shape (e.g. a truncated rewrite that
    left just "null"). Results are cached so a file tracked under several
    keys is parsed (and reported unreadable) once.
    """
    if path in _METRICS_CACHE:
        return _METRICS_CACHE[path]
    try:
        with open(path) as f:
            doc = json.load(f)
        if not isinstance(doc, dict):
            raise ValueError(f"expected a JSON object, got {type(doc).__name__}")
        result = doc.get("metrics", {})
        if not isinstance(result, dict):
            raise ValueError(f"'metrics' is {type(result).__name__}, not an object")
    except (json.JSONDecodeError, OSError, ValueError) as e:
        print(f"{path}: unreadable ({e})")
        result = None
    _METRICS_CACHE[path] = result
    return result


def check_sharded_threads(new_dir: Path, failures):
    """Hard gate: the fresh tab2 sharded section ran at >= 8 threads."""
    fname = "BENCH_tab2_manticore.json"
    new_file = new_dir / fname
    if not new_file.exists():
        return  # the tracked-metric loop reports the missing file
    new_metrics = metrics(new_file)
    if new_metrics is None:
        return  # likewise
    threads = new_metrics.get("sharded_threads")
    if threads is None or threads < MIN_SHARDED_THREADS:
        failures.append(
            f"{fname}: sharded_threads is {threads!r}, scaling gate requires "
            f">= {MIN_SHARDED_THREADS} (set NOC_BENCH_THREADS)"
        )
    else:
        print(f"{fname}: sharded_threads = {threads:g} (gate >= {MIN_SHARDED_THREADS}) ok")


def check_telemetry_overhead(new_dir: Path, failures):
    """Hard gate: telemetry attach cost stays under MAX_TELEMETRY_OVERHEAD."""
    fname = "BENCH_tab2_manticore.json"
    new_file = new_dir / fname
    if not new_file.exists():
        return  # the tracked-metric loop reports the missing file
    new_metrics = metrics(new_file)
    if new_metrics is None:
        return  # likewise
    frac = new_metrics.get("telemetry_overhead_frac")
    if frac is None:
        failures.append(f"{fname}: telemetry_overhead_frac missing from fresh results")
    elif frac > MAX_TELEMETRY_OVERHEAD:
        failures.append(
            f"{fname}: telemetry_overhead_frac = {frac:.3f}, gate "
            f"<= {MAX_TELEMETRY_OVERHEAD:.2f}"
        )
    else:
        print(
            f"{fname}: telemetry_overhead_frac = {frac:.3f} "
            f"(gate <= {MAX_TELEMETRY_OVERHEAD:.2f}) ok"
        )


def main(argv):
    if len(argv) != 3:
        print(__doc__)
        return 2
    prev_dir, new_dir = Path(argv[1]), Path(argv[2])
    failures = []
    check_sharded_threads(new_dir, failures)
    check_telemetry_overhead(new_dir, failures)
    if not prev_dir.is_dir():
        print(f"no previous bench artifact at {prev_dir}; skipping trend check")
        if failures:
            print("\nbench trend check FAILED:")
            for f in failures:
                print(f"  - {f}")
            return 1
        return 0
    for entry in TRACKED:
        fname, key = entry["file"], entry["key"]
        threshold = entry.get("threshold", THRESHOLD)
        mode = entry.get("mode", "relative")
        condition = entry.get("condition")
        prev_file, new_file = prev_dir / fname, new_dir / fname
        if not prev_file.exists():
            print(f"{fname}:{key}: no previous copy, skipping")
            continue
        if not new_file.exists():
            failures.append(f"{fname}: missing from the fresh results")
            continue
        prev_metrics = metrics(prev_file)
        if prev_metrics is None:
            print(f"{fname}:{key}: unreadable previous artifact, skipping")
            continue
        new_metrics = metrics(new_file)
        if new_metrics is None:
            msg = f"{fname}: fresh results are unreadable"
            if msg not in failures:
                failures.append(msg)
            continue
        if condition is not None:
            prev_cond = prev_metrics.get(condition)
            new_cond = new_metrics.get(condition)
            if prev_cond != new_cond:
                print(
                    f"{fname}:{key}: {condition} changed "
                    f"({prev_cond!r} -> {new_cond!r}), not comparable, skipping"
                )
                continue
        prev = prev_metrics.get(key)
        new = new_metrics.get(key)
        if prev is None:
            print(f"{fname}:{key}: no previous value, skipping")
            continue
        if new is None:
            failures.append(f"{fname}:{key}: metric missing from fresh results")
            continue
        if mode == "abs-increase":
            # Lower-is-better fraction: gate on absolute growth (a ratio
            # against a near-zero baseline would be all noise). Zero is a
            # legitimate value here.
            change = new - prev
            regressed = change > threshold
            print(
                f"{fname}:{key}: {prev:.4g} -> {new:.4g} "
                f"({change:+.3f} abs, gate +{threshold:.2f}) "
                f"{'REGRESSION' if regressed else 'ok'}"
            )
            if regressed:
                failures.append(
                    f"{fname}:{key} grew {change:+.3f} ({prev:.4g} -> {new:.4g})"
                )
            continue
        if mode == "drift":
            # Deterministic simulated value with no better/worse
            # direction: gate on relative movement either way.
            if prev <= 0:
                print(f"{fname}:{key}: no positive previous value, skipping")
                continue
            change = (new - prev) / prev
            regressed = abs(change) > threshold
            print(
                f"{fname}:{key}: {prev:.4g} -> {new:.4g} "
                f"({change:+.1%}, drift gate ±{threshold:.0%}) "
                f"{'REGRESSION' if regressed else 'ok'}"
            )
            if regressed:
                failures.append(
                    f"{fname}:{key} drifted {change:+.1%} ({prev:.4g} -> {new:.4g})"
                )
            continue
        if prev <= 0:
            print(f"{fname}:{key}: no positive previous value, skipping")
            continue
        if new <= 0:
            # A throughput of zero (or less) is a broken measurement, not
            # a regression ratio worth computing.
            failures.append(f"{fname}:{key}: fresh value {new!r} is not positive")
            continue
        change = (new - prev) / prev
        regressed = change < -threshold
        print(
            f"{fname}:{key}: {prev:.4g} -> {new:.4g} "
            f"({change:+.1%}, gate {threshold:.0%}) {'REGRESSION' if regressed else 'ok'}"
        )
        if regressed:
            failures.append(
                f"{fname}:{key} regressed {change:+.1%} ({prev:.4g} -> {new:.4g})"
            )
    if failures:
        print("\nbench trend check FAILED (regression past gate):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench trend check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
